//! Fault-injection tests of the replicated shard service (ISSUE 4):
//! replica placement, write-through puts, mid-fetch shard death with
//! transparent failover, and storage-node admission control.
//!
//! Acceptance contracts:
//! * with `replication = 2`, killing any single shard at a chunk
//!   boundary mid-fetch still restores the demo prefix bit-identically,
//!   and the report names which replica served each chunk;
//! * for random token chains, every chunk's replica set holds `r`
//!   distinct shards (both placements), write-through puts land on
//!   exactly those shards, and the fleet prefix lookup survives a dead
//!   primary;
//! * a saturated node answers `Busy` (never drops the connection), the
//!   excess requests succeed after backoff, and the server-side
//!   in-flight byte counter never exceeds `max_inflight`;
//! * when *every* replica of a chunk is saturated past the retry
//!   budget, the fetch surfaces `FetchError::Capacity`.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use kvfetcher::asic::{h20_table, DecodePool};
use kvfetcher::baselines::SystemProfile;
use kvfetcher::engine::ExecMode;
use kvfetcher::fetcher::{
    ChunkPayload, FetchConfig, FetchError, FetchRequest, Fetcher, ResolutionPolicy,
};
use kvfetcher::kvstore::{prefix_hashes, StorageNode};
use kvfetcher::net::BandwidthTrace;
use kvfetcher::service::{
    demo_prefix, protocol, AdmissionConfig, Backend, DemoPrefix, Placement, Response,
    RetryPolicy, ServerConfig, ShardMap, ShardRouter, SourceRegistry, SourceSpec, StorageServer,
    StoreClient, ThrottleSpec, DEMO_HEADS, DEMO_HEAD_DIM, DEMO_LADDER, DEMO_PLANES,
};
use kvfetcher::util::Prng;

// ---------------------------------------------------------- FaultPlan

/// Declarative fault/limit plan for a loopback shard fleet: which shard
/// dies at which chunk boundary, which delays accepts or forces `Busy`,
/// and each node's admission limits. `launch` spawns the servers and
/// registers the demo chunks through a replicated router (write-through
/// `PutChunk` over the wire), returning the live fleet.
struct FaultPlan {
    replication: usize,
    placement: Placement,
    cfgs: Vec<ServerConfig>,
}

impl FaultPlan {
    fn new(n_shards: usize, replication: usize) -> FaultPlan {
        FaultPlan {
            replication,
            placement: Placement::RoundRobin,
            cfgs: vec![ServerConfig::default(); n_shards],
        }
    }

    fn placement(mut self, placement: Placement) -> FaultPlan {
        self.placement = placement;
        self
    }

    /// Kill `shard` after it has served `fetches` chunk fetches.
    fn kill_after(mut self, shard: usize, fetches: usize) -> FaultPlan {
        self.cfgs[shard].fault.die_after_fetches = Some(fetches);
        self
    }

    /// Force `Busy` on `shard`'s first `n` chunk-fetch requests.
    fn busy_first(mut self, shard: usize, n: usize) -> FaultPlan {
        self.cfgs[shard].fault.busy_first_fetches = n;
        self
    }

    /// Delay every accept on `shard` by `ms` milliseconds.
    fn delay_accepts(mut self, shard: usize, ms: u64) -> FaultPlan {
        self.cfgs[shard].fault.accept_delay_ms = ms;
        self
    }

    fn launch(&self, demo: &DemoPrefix) -> Fleet {
        let mut servers = Vec::new();
        let mut addrs = Vec::new();
        for cfg in &self.cfgs {
            let node = StorageNode::new(demo.chunk_tokens);
            let server = StorageServer::spawn("127.0.0.1:0", node, cfg.clone()).expect("bind");
            addrs.push(server.local_addr().to_string());
            servers.push(server);
        }
        let router = ShardRouter::connect_replicated(&addrs, self.placement, self.replication)
            .expect("connect fleet");
        for (i, chunk) in demo.chunks.iter().enumerate() {
            let out = router.put_chunk(i, chunk);
            assert!(out.all_stored(), "chunk {i} must register on every replica: {out:?}");
        }
        drop(router); // free the populate connections
        Fleet { servers, addrs, replication: self.replication, placement: self.placement }
    }
}

struct Fleet {
    servers: Vec<StorageServer>,
    addrs: Vec<String>,
    replication: usize,
    placement: Placement,
}

impl Fleet {
    /// A TCP source spec over this fleet, with a fast retry policy so
    /// busy faults resolve in test time.
    fn source_spec(&self, demo: &DemoPrefix) -> SourceSpec {
        let mut spec = SourceSpec::new(demo.hashes.clone(), DEMO_LADDER);
        spec.addrs = self.addrs.clone();
        spec.placement = self.placement;
        spec.replication = self.replication;
        spec.tokens = demo.tokens.clone();
        spec.chunk_tokens = demo.chunk_tokens;
        spec.retry = RetryPolicy { max_busy_retries: 6, min_backoff_ms: 2, max_backoff_ms: 50 };
        spec
    }

    fn map(&self) -> ShardMap {
        ShardMap::with_replication(self.servers.len(), self.placement, self.replication)
    }

    fn shutdown(self) {
        for s in self.servers {
            s.shutdown();
        }
    }
}

fn demo_request(demo: &DemoPrefix, n_chunks: usize) -> FetchRequest {
    let total_tokens = n_chunks * demo.chunk_tokens;
    FetchRequest::new(total_tokens, total_tokens * DEMO_PLANES * DEMO_HEADS * DEMO_HEAD_DIM * 2)
        .with_hashes(demo.hashes.clone())
        .resolution(ResolutionPolicy::Fixed(0))
        .exec(ExecMode::Pipelined)
}

fn demo_fetcher(demo: &DemoPrefix, replication: usize) -> Fetcher {
    Fetcher::builder()
        .profile(SystemProfile::kvfetcher())
        .fetch_config(FetchConfig { chunk_tokens: demo.chunk_tokens, ..Default::default() })
        .bandwidth(BandwidthTrace::constant(8.0))
        .decode_pool(DecodePool::new(7, h20_table()))
        .replication(replication)
        .build()
}

/// Exact frame cost of serving one demo chunk's 144p payload — the unit
/// the server's in-flight accounting reserves.
fn chunk_frame_len(demo: &DemoPrefix, idx: usize) -> usize {
    let chunk = &demo.chunks[idx];
    let v = chunk.variant("144p").expect("144p stored");
    let payload = ChunkPayload {
        hash: chunk.hash,
        tokens: chunk.tokens,
        resolution: "144p".into(),
        scales: chunk.scales.clone(),
        group_bytes: v.group_bytes.clone(),
    };
    let (tag, body) = protocol::encode_response(&Response::Chunk(payload));
    protocol::frame_bytes(tag, &body).len()
}

// ------------------------------------------------- failover acceptance

/// Acceptance: with replication=2 on 3 shards, killing *any* single
/// shard after its first served chunk still restores the whole demo
/// prefix bit-identically, and the wire timings name the replica that
/// served each chunk (at least one chunk must have failed over).
#[test]
fn killing_any_single_shard_mid_fetch_restores_bit_identical() {
    let n_chunks = 6;
    for victim in 0..3usize {
        let demo = demo_prefix(31 + victim as u64, n_chunks, 32);
        let fleet = FaultPlan::new(3, 2).kill_after(victim, 1).launch(&demo);
        let spec = fleet.source_spec(&demo);
        let source =
            SourceRegistry::with_defaults().create(Backend::Tcp, &spec).expect("tcp source");
        let mut session =
            demo_fetcher(&demo, 2).session(demo_request(&demo, n_chunks)).with_source(source);
        session.run().unwrap_or_else(|e| panic!("victim {victim}: failover must complete: {e}"));
        let report = session.take_report().expect("report stored");
        assert!(!report.aborted, "victim {victim}");
        assert_eq!(report.restored.len(), n_chunks, "victim {victim}");
        for (d, q) in report.restored.iter().zip(&demo.quants) {
            assert_eq!(d.quant.data, q.data, "victim {victim}: restore must be bit-exact");
            assert_eq!(d.quant.scales, q.scales, "victim {victim}");
        }

        // the harness reports which replica served each chunk; served
        // shards must come from the chunk's replica set, and the chunks
        // the dead primary owned past the boundary came from replica 1
        assert_eq!(report.wire_timings.len(), n_chunks);
        let map = fleet.map();
        let mut failed_over = 0usize;
        for t in &report.wire_timings {
            let replicas = map.replicas_of(t.idx, demo.hashes[t.idx]);
            let served = t.shard.expect("tcp source names the serving shard");
            assert!(
                replicas.contains(&served),
                "victim {victim}: chunk {} served by non-replica shard {served}",
                t.idx
            );
            if served != replicas[0] {
                assert_eq!(served, replicas[1], "failover follows replica order");
                failed_over += 1;
            }
        }
        assert!(failed_over >= 1, "victim {victim}: no chunk failed over to a replica");
        fleet.shutdown();
    }
}

/// Forced `Busy` replies and delayed accepts are absorbed by the retry
/// policy: the fetch completes bit-exact and the refusals are visible
/// in the faulty node's counters.
#[test]
fn forced_busy_and_slow_accepts_are_ridden_out() {
    let n_chunks = 4;
    let demo = demo_prefix(71, n_chunks, 32);
    let fleet = FaultPlan::new(2, 2).busy_first(0, 2).delay_accepts(1, 40).launch(&demo);
    let spec = fleet.source_spec(&demo);
    let source = SourceRegistry::with_defaults().create(Backend::Tcp, &spec).expect("tcp source");
    let mut session =
        demo_fetcher(&demo, 2).session(demo_request(&demo, n_chunks)).with_source(source);
    session.run().expect("busy faults must be retried through");
    let report = session.take_report().expect("report stored");
    assert_eq!(report.restored.len(), n_chunks);
    for (d, q) in report.restored.iter().zip(&demo.quants) {
        assert_eq!(d.quant.data, q.data, "restore must be bit-exact despite busy faults");
    }
    let stats = StoreClient::connect(&fleet.addrs[0]).expect("connect").stats().expect("stats");
    assert_eq!(stats.busy_replies, 2, "both forced refusals were issued");
    fleet.shutdown();
}

// ------------------------------------------------- placement property

/// Property: across shard counts, replication factors 1..=3, and both
/// placements, every chunk of a random token chain is mapped to
/// `min(r, n)` *distinct* shards, primary first.
#[test]
fn replica_sets_cover_r_distinct_shards_for_random_chains() {
    let mut prng = Prng::new(0xFA17);
    for n_shards in 1..=5usize {
        for r in 1..=3usize {
            for placement in [Placement::RoundRobin, Placement::ByHash] {
                let map = ShardMap::with_replication(n_shards, placement, r);
                let eff = r.min(n_shards);
                assert_eq!(map.replication(), eff);
                let tokens: Vec<u32> = (0..27 * 8).map(|_| prng.next_u64() as u32).collect();
                let hashes = prefix_hashes(&tokens, 8);
                assert!(hashes.len() >= 27);
                for (i, &h) in hashes.iter().enumerate() {
                    let reps = map.replicas_of(i, h);
                    assert_eq!(reps.len(), eff, "{placement:?} n={n_shards} r={r}");
                    assert_eq!(reps[0], map.shard_of(i, h), "primary leads the set");
                    let unique: HashSet<usize> = reps.iter().copied().collect();
                    assert_eq!(unique.len(), eff, "replicas collide: {reps:?}");
                    assert!(reps.iter().all(|&s| s < n_shards));
                }
            }
        }
    }
}

/// Write-through puts land every chunk on exactly its replica set (both
/// placements, checked over the wire), and the fleet prefix lookup
/// still finds the whole chain after the primary-holding shard dies.
#[test]
fn write_through_reaches_every_replica_and_lookup_survives_death() {
    let demo = demo_prefix(41, 5, 32);
    for placement in [Placement::RoundRobin, Placement::ByHash] {
        let mut fleet = FaultPlan::new(3, 2).placement(placement).launch(&demo);
        let map = fleet.map();
        let clients: Vec<StoreClient> =
            fleet.addrs.iter().map(|a| StoreClient::connect(a).expect("connect")).collect();
        for (i, &h) in demo.hashes.iter().enumerate() {
            let holders: Vec<usize> = (0..3)
                .filter(|&s| clients[s].has_chunks(&[h]).expect("probe")[0])
                .collect();
            let mut replicas = map.replicas_of(i, h);
            replicas.sort_unstable();
            assert_eq!(holders, replicas, "{placement:?}: chunk {i} on the wrong shards");
        }
        drop(clients);

        let router =
            ShardRouter::connect_replicated(&fleet.addrs, placement, 2).expect("connect");
        assert_eq!(
            router.match_prefix(&demo.tokens, demo.chunk_tokens).expect("fleet lookup"),
            demo.hashes
        );
        // kill shard 0: every chunk it held still resolves via replicas
        fleet.servers.remove(0).shutdown();
        assert_eq!(
            router.match_prefix(&demo.tokens, demo.chunk_tokens).expect("degraded lookup"),
            demo.hashes,
            "{placement:?}: lookup must survive a dead shard"
        );
        fleet.shutdown();
    }
}

// --------------------------------------------------- admission control

/// Acceptance: a 1-shard node under parallel clients answers `Busy` at
/// its in-flight byte cap instead of dropping connections, the refused
/// clients succeed after backoff, and the server-side counter proves
/// `max_inflight` was never exceeded.
#[test]
fn saturated_node_returns_busy_then_succeeds_and_inflight_is_capped() {
    let demo = demo_prefix(53, 1, 48);
    let frame_len = chunk_frame_len(&demo, 0);
    // fits one reply in flight, never two
    let max_inflight = frame_len + frame_len / 2;
    // pace the wire so one reply takes ~80ms: concurrent fetches must
    // overlap and collide with the cap
    let gbps = (frame_len as f64 * 8.0) / (0.080 * 1e9);
    let mut node = StorageNode::new(demo.chunk_tokens);
    node.register(demo.chunks[0].clone());
    let cfg = ServerConfig {
        throttle: Some(ThrottleSpec::new(BandwidthTrace::constant(gbps), 1.0)),
        admission: AdmissionConfig { max_inflight_bytes: max_inflight, ..Default::default() },
        ..Default::default()
    };
    let server = StorageServer::spawn("127.0.0.1:0", node, cfg).expect("bind");
    let addr = server.local_addr().to_string();

    let busy_seen = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..4 {
            s.spawn(|| {
                let client = StoreClient::connect(&addr).expect("connect");
                let mut retries = 0usize;
                loop {
                    match client.fetch_chunk(demo.hashes[0], "144p") {
                        Ok(Some(p)) => {
                            assert_eq!(p.hash, demo.hashes[0]);
                            break;
                        }
                        Ok(None) => panic!("chunk must be stored"),
                        Err(e) => match FetchError::from_io(&e) {
                            Some(FetchError::Busy { retry_after_ms }) => {
                                busy_seen.fetch_add(1, Ordering::SeqCst);
                                retries += 1;
                                assert!(retries < 200, "no progress after 200 busy retries");
                                std::thread::sleep(Duration::from_millis(
                                    retry_after_ms.clamp(5, 50),
                                ));
                            }
                            other => panic!("connection dropped instead of Busy: {e} {other:?}"),
                        },
                    }
                }
            });
        }
    });
    assert!(
        busy_seen.load(Ordering::SeqCst) >= 1,
        "parallel fetches over the cap must see Busy"
    );

    let stats = StoreClient::connect(&addr).expect("connect").stats().expect("stats");
    assert!(stats.busy_replies >= busy_seen.load(Ordering::SeqCst) as u64);
    assert!(
        (stats.peak_inflight_bytes as usize) <= max_inflight,
        "in-flight bytes exceeded the cap: {} > {max_inflight}",
        stats.peak_inflight_bytes
    );
    assert!((stats.peak_inflight_bytes as usize) >= frame_len, "at least one reply was metered");
    assert_eq!(stats.inflight_bytes, 0, "all reservations released");
    server.shutdown();
}

/// Over the connection limit, data-plane requests are refused with
/// `Busy` (the connection is not dropped, and the control plane stays
/// reachable); once the other connection closes, the refused client
/// succeeds.
#[test]
fn connection_limit_refuses_busy_then_recovers() {
    let demo = demo_prefix(83, 1, 32);
    let mut node = StorageNode::new(demo.chunk_tokens);
    node.register(demo.chunks[0].clone());
    let cfg = ServerConfig {
        admission: AdmissionConfig { max_conns: 1, ..Default::default() },
        ..Default::default()
    };
    let server = StorageServer::spawn("127.0.0.1:0", node, cfg).expect("bind");
    let addr = server.local_addr().to_string();

    let first = StoreClient::connect(&addr).expect("connect");
    assert!(first.fetch_chunk(demo.hashes[0], "144p").expect("within limit").is_some());

    let second = StoreClient::connect(&addr).expect("connect");
    let err = second.fetch_chunk(demo.hashes[0], "144p").expect_err("over the limit");
    assert!(
        matches!(FetchError::from_io(&err), Some(FetchError::Busy { .. })),
        "expected a typed Busy refusal, got {err}"
    );
    // control plane still answers while saturated
    assert!(second.stats().expect("stats stay reachable").busy_replies >= 1);

    drop(first);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match second.fetch_chunk(demo.hashes[0], "144p") {
            Ok(Some(_)) => break,
            Err(e) if matches!(FetchError::from_io(&e), Some(FetchError::Busy { .. })) => {
                assert!(Instant::now() < deadline, "connection slot never freed");
                std::thread::sleep(Duration::from_millis(20));
            }
            other => panic!("unexpected result {other:?}"),
        }
    }
    server.shutdown();
}

/// When *every* replica of a chunk is saturated past the retry budget,
/// the sourced fetch surfaces `FetchError::Capacity` (not a transport
/// error), and the session keeps the partial report.
#[test]
fn all_replicas_saturated_surfaces_capacity() {
    let n_chunks = 2;
    let demo = demo_prefix(67, n_chunks, 32);
    let fleet =
        FaultPlan::new(2, 2).busy_first(0, 100_000).busy_first(1, 100_000).launch(&demo);
    let mut spec = fleet.source_spec(&demo);
    spec.retry = RetryPolicy { max_busy_retries: 2, min_backoff_ms: 1, max_backoff_ms: 5 };
    let source = SourceRegistry::with_defaults().create(Backend::Tcp, &spec).expect("tcp source");
    let mut session =
        demo_fetcher(&demo, 2).session(demo_request(&demo, n_chunks)).with_source(source);
    match session.run() {
        Err(FetchError::Capacity { detail }) => {
            assert!(detail.contains("saturated"), "{detail}")
        }
        other => panic!("wrong result {:?}", other.err()),
    }
    let report = session.report().expect("partial report kept");
    assert!(report.aborted);
    assert!(report.restored.is_empty());
    fleet.shutdown();
}
