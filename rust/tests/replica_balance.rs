//! Replica read load-balancing + anti-entropy repair (ISSUE 5).
//!
//! Acceptance contracts:
//! * with replication >= 2 and a `round-robin` or `least-inflight`
//!   read policy, a multi-chunk fetch is served by >= 2 distinct
//!   replicas (asserted on `WireTiming::shard` histograms) and still
//!   restores bit-identically;
//! * `least-inflight` steers every chunk away from a replica whose
//!   `NodeStats.inflight_bytes` is pinned high, and `estimator-weighted`
//!   probes every replica once before settling on the fastest link;
//! * kill shard -> rejoin empty -> `RepairScanner::repair` converges
//!   every chunk's holder set back to replication factor `r`, restores
//!   stay bit-identical, and a second pass is a no-op;
//! * repair transfers ride the admission `Busy` handshake (bounded
//!   backoff) instead of stampeding a refusing holder.

use std::collections::BTreeMap;
use std::thread;
use std::time::{Duration, Instant};

use kvfetcher::asic::{h20_table, DecodePool};
use kvfetcher::baselines::SystemProfile;
use kvfetcher::fetcher::{
    ExecMode, FetchConfig, FetchReport, FetchRequest, Fetcher, ReadPolicy, ResolutionPolicy,
};
use kvfetcher::kvstore::StorageNode;
use kvfetcher::net::BandwidthTrace;
use kvfetcher::service::{
    demo_prefix, Backend, DemoPrefix, Placement, RepairScanner, RetryPolicy, ServerConfig,
    ShardMap, ShardRouter, SourceRegistry, SourceSpec, StorageServer, StoreClient, ThrottleSpec,
    DEMO_HEADS, DEMO_HEAD_DIM, DEMO_LADDER, DEMO_PLANES,
};

/// Spawn one server per shard, populated *in-process* with the demo
/// chunks each shard's replica set owns (write-through-over-the-wire is
/// `tests/service_faults.rs` territory; here population must not ride
/// a throttled socket).
fn launch(
    demo: &DemoPrefix,
    replication: usize,
    cfgs: Vec<ServerConfig>,
) -> (Vec<StorageServer>, Vec<String>, ShardMap) {
    let map = ShardMap::with_replication(cfgs.len(), Placement::RoundRobin, replication);
    let mut nodes: Vec<StorageNode> =
        (0..cfgs.len()).map(|_| StorageNode::new(demo.chunk_tokens)).collect();
    for (i, chunk) in demo.chunks.iter().enumerate() {
        for shard in map.replicas_of(i, chunk.hash) {
            assert!(nodes[shard].register(chunk.clone()).stored);
        }
    }
    let mut servers = Vec::new();
    let mut addrs = Vec::new();
    for (node, cfg) in nodes.into_iter().zip(cfgs) {
        let server = StorageServer::spawn("127.0.0.1:0", node, cfg).expect("bind");
        addrs.push(server.local_addr().to_string());
        servers.push(server);
    }
    (servers, addrs, map)
}

fn spec_for(demo: &DemoPrefix, addrs: &[String], replication: usize) -> SourceSpec {
    let mut spec = SourceSpec::new(demo.hashes.clone(), DEMO_LADDER);
    spec.addrs = addrs.to_vec();
    spec.placement = Placement::RoundRobin;
    spec.replication = replication;
    spec.tokens = demo.tokens.clone();
    spec.chunk_tokens = demo.chunk_tokens;
    spec.retry = RetryPolicy { max_busy_retries: 6, min_backoff_ms: 2, max_backoff_ms: 50 };
    spec
}

/// Run one pipelined demo fetch through the facade under `policy` and
/// return its report (bit-exactness asserted here for every caller).
fn policy_fetch(
    demo: &DemoPrefix,
    addrs: &[String],
    replication: usize,
    policy: ReadPolicy,
) -> FetchReport {
    let mut spec = spec_for(demo, addrs, replication);
    spec.read_policy = policy;
    let source = SourceRegistry::with_defaults().create(Backend::Tcp, &spec).expect("tcp source");
    let n_chunks = demo.hashes.len();
    let total_tokens = n_chunks * demo.chunk_tokens;
    let req = FetchRequest::new(
        total_tokens,
        total_tokens * DEMO_PLANES * DEMO_HEADS * DEMO_HEAD_DIM * 2,
    )
    .with_hashes(demo.hashes.clone())
    .resolution(ResolutionPolicy::Fixed(0))
    .exec(ExecMode::Pipelined);
    let fetcher = Fetcher::builder()
        .profile(SystemProfile::kvfetcher())
        .fetch_config(FetchConfig { chunk_tokens: demo.chunk_tokens, ..Default::default() })
        .bandwidth(BandwidthTrace::constant(8.0))
        .decode_pool(DecodePool::new(7, h20_table()))
        .replication(replication)
        .read_policy(policy)
        .build();
    let mut session = fetcher.session(req).with_source(source);
    session.run().unwrap_or_else(|e| panic!("{policy} fetch must complete: {e}"));
    let report = session.take_report().expect("report stored");
    assert_eq!(report.restored.len(), n_chunks, "{policy}");
    for (d, q) in report.restored.iter().zip(&demo.quants) {
        assert_eq!(d.quant.data, q.data, "{policy}: restore must be bit-exact");
        assert_eq!(d.quant.scales, q.scales, "{policy}");
    }
    assert_eq!(report.wire_timings.len(), n_chunks, "{policy}");
    report
}

/// Serving-shard histogram of a report, with replica-set membership
/// asserted for every chunk.
fn shard_histogram(
    report: &FetchReport,
    demo: &DemoPrefix,
    map: &ShardMap,
) -> BTreeMap<usize, usize> {
    let mut hist = BTreeMap::new();
    for t in &report.wire_timings {
        let served = t.shard.expect("tcp source names the serving shard");
        let replicas = map.replicas_of(t.idx, demo.hashes[t.idx]);
        assert!(replicas.contains(&served), "chunk {} served off-replica-set", t.idx);
        *hist.entry(served).or_insert(0usize) += 1;
    }
    hist
}

// ----------------------------------------------------- read balancing

/// Acceptance: round-robin on 3 shards / replication 2 serves a
/// 6-chunk fetch from >= 2 distinct replicas (guaranteed here: the
/// three primaries' candidate sets {0,1}/{1,2}/{2,0} share no common
/// element), each chunk from exactly the replica the hash-keyed
/// rotation predicts.
#[test]
fn round_robin_spreads_reads_across_replicas() {
    let demo = demo_prefix(101, 6, 32);
    let (servers, addrs, map) = launch(&demo, 2, vec![ServerConfig::default(); 3]);
    let report = policy_fetch(&demo, &addrs, 2, ReadPolicy::RoundRobin);
    let hist = shard_histogram(&report, &demo, &map);
    assert!(hist.len() >= 2, "round-robin must hit >= 2 distinct replicas: {hist:?}");
    // the rotation is deterministic and keyed on the chunk hash (a
    // chain-position rotation would alias with the placement stripe)
    for t in &report.wire_timings {
        let expected = map.rotated_replicas_of(t.idx, demo.hashes[t.idx])[0];
        assert_eq!(t.shard, Some(expected), "chunk {} rotated wrong", t.idx);
    }
    for s in servers {
        s.shutdown();
    }
}

/// With nothing in flight anywhere, least-inflight degrades to
/// primary-first order — which on a round-robin-placed chain already
/// stripes the fetch across every shard.
#[test]
fn least_inflight_serves_primaries_when_fleet_is_idle() {
    let demo = demo_prefix(103, 6, 32);
    let (servers, addrs, map) = launch(&demo, 2, vec![ServerConfig::default(); 3]);
    let report = policy_fetch(&demo, &addrs, 2, ReadPolicy::LeastInflight);
    let hist = shard_histogram(&report, &demo, &map);
    assert!(hist.len() >= 2, "idle least-inflight must still spread: {hist:?}");
    for t in &report.wire_timings {
        let primary = map.replicas_of(t.idx, demo.hashes[t.idx])[0];
        assert_eq!(t.shard, Some(primary), "ties must keep primary-first order");
    }
    for s in servers {
        s.shutdown();
    }
}

/// Acceptance: least-inflight reads the wire-v2 `NodeStats.inflight`
/// signal — a replica with bytes pinned in flight serves nothing while
/// its peer is idle.
#[test]
fn least_inflight_avoids_the_loaded_replica() {
    let demo = demo_prefix(107, 6, 32);
    // shard 0 paces every write very slowly, so one background fetch
    // pins its in-flight reservation for seconds
    let slow = ServerConfig {
        throttle: Some(ThrottleSpec::new(BandwidthTrace::constant(8e-5), 1.0)),
        ..Default::default()
    };
    let (servers, addrs, map) = launch(&demo, 2, vec![slow, ServerConfig::default()]);

    let pin_addr = addrs[0].clone();
    let pin_hash = demo.hashes[0];
    let pinner = thread::spawn(move || {
        let client = StoreClient::connect(&pin_addr).expect("connect");
        let payload = client.fetch_chunk(pin_hash, "144p").expect("paced fetch");
        assert!(payload.is_some(), "shard 0 stores chunk 0");
    });
    // wait until the paced reply's reservation is visible in Stats
    let probe = StoreClient::connect(&addrs[0]).expect("connect");
    let deadline = Instant::now() + Duration::from_secs(10);
    while probe.stats().expect("stats").inflight_bytes == 0 {
        assert!(Instant::now() < deadline, "pinned reservation never appeared");
        thread::sleep(Duration::from_millis(5));
    }

    let report = policy_fetch(&demo, &addrs, 2, ReadPolicy::LeastInflight);
    let hist = shard_histogram(&report, &demo, &map);
    assert_eq!(
        hist.get(&1).copied().unwrap_or(0),
        demo.hashes.len(),
        "every chunk must dodge the loaded replica: {hist:?}"
    );
    pinner.join().expect("pinned fetch completes");
    for s in servers {
        s.shutdown();
    }
}

/// Estimator-weighted reads probe each replica once (unobserved links
/// sort first), then route everything over the faster link.
#[test]
fn estimator_weighted_probes_once_then_prefers_the_fast_link() {
    let demo = demo_prefix(109, 6, 32);
    // shard 0's wire is ~3 orders of magnitude slower than loopback
    let slow = ServerConfig {
        throttle: Some(ThrottleSpec::new(BandwidthTrace::constant(2e-3), 1.0)),
        ..Default::default()
    };
    let (servers, addrs, map) = launch(&demo, 2, vec![slow, ServerConfig::default()]);
    let report = policy_fetch(&demo, &addrs, 2, ReadPolicy::EstimatorWeighted);
    let hist = shard_histogram(&report, &demo, &map);
    assert_eq!(hist.len(), 2, "both replicas must be probed: {hist:?}");
    assert_eq!(hist.get(&0), Some(&1), "the slow replica serves only its probe: {hist:?}");
    assert_eq!(report.wire_timings[0].shard, Some(0), "first chunk probes the primary");
    for s in servers {
        s.shutdown();
    }
}

// -------------------------------------------------- anti-entropy repair

/// Acceptance: kill a shard, rejoin it empty, run repair — every
/// chunk's holder set is back at factor r, the restore is
/// bit-identical, and a second pass repairs nothing.
#[test]
fn repair_converges_after_kill_and_rejoin() {
    let demo = demo_prefix(113, 6, 32);
    let (mut servers, addrs, map) = launch(&demo, 2, vec![ServerConfig::default(); 3]);
    let expected_deficit = (0..demo.hashes.len())
        .filter(|&i| map.replicas_of(i, demo.hashes[i]).contains(&1))
        .count();
    assert!(expected_deficit >= 2, "victim must replicate several chunks");

    // healthy fleet scans clean
    let router =
        ShardRouter::connect_replicated(&addrs, Placement::RoundRobin, 2).expect("connect");
    assert!(RepairScanner::new(router).scan(&demo.hashes).healthy());

    // kill shard 1 — the degraded fleet is still scannable (lenient)
    servers.remove(1).shutdown();
    let (router, dead) =
        ShardRouter::connect_lenient(&addrs, Placement::RoundRobin, 2).expect("lenient");
    assert_eq!(dead, vec![1]);
    let degraded = RepairScanner::new(router).scan(&demo.hashes);
    assert_eq!(degraded.unreachable_shards, vec![1]);
    assert_eq!(degraded.under_replicated(), expected_deficit);

    // shard 1 rejoins with nothing (same address, fresh node)
    let blank = StorageNode::new(demo.chunk_tokens);
    let rejoined = StorageServer::spawn(&addrs[1], blank, ServerConfig::default())
        .expect("rebind freed port");
    servers.insert(1, rejoined);

    let router =
        ShardRouter::connect_replicated(&addrs, Placement::RoundRobin, 2).expect("connect");
    let scanner = RepairScanner::new(router);
    let report = scanner.repair(&demo.hashes);
    assert!(report.converged(), "failed: {:?}", report.failed);
    assert_eq!(report.repaired.len(), expected_deficit);
    assert!(report.repaired.iter().all(|a| a.to == 1), "only the rejoined shard was short");
    assert!(scanner.scan(&demo.hashes).healthy(), "fleet must be back at factor r");

    // holder sets equal the replica sets, over the wire
    let clients: Vec<StoreClient> =
        addrs.iter().map(|a| StoreClient::connect(a).expect("connect")).collect();
    for (i, &h) in demo.hashes.iter().enumerate() {
        let holders: Vec<usize> =
            (0..3).filter(|&s| clients[s].has_chunks(&[h]).expect("probe")[0]).collect();
        let mut replicas = map.replicas_of(i, h);
        replicas.sort_unstable();
        assert_eq!(holders, replicas, "chunk {i} holder set after repair");
    }
    drop(clients);

    // the healed fleet serves balanced reads bit-identically
    let fetched = policy_fetch(&demo, &addrs, 2, ReadPolicy::RoundRobin);
    assert!(shard_histogram(&fetched, &demo, &map).contains_key(&1), "rejoined shard serves");

    // idempotent: nothing left to move
    let again = scanner.repair(&demo.hashes);
    assert!(again.repaired.is_empty() && again.failed.is_empty());
    for s in servers {
        s.shutdown();
    }
}

/// Repair transfers are rate-limited by the admission `Busy` handshake:
/// a holder that refuses the first pulls is retried with backoff, and
/// the pass still converges.
#[test]
fn repair_rides_out_busy_holders() {
    let demo = demo_prefix(127, 3, 32);
    let busy_holder = ServerConfig {
        fault: kvfetcher::service::FaultSpec { busy_first_fetches: 2, ..Default::default() },
        ..Default::default()
    };
    let (mut servers, addrs, _map) =
        launch(&demo, 2, vec![busy_holder, ServerConfig::default()]);

    // shard 1 dies and rejoins empty; shard 0 is the only holder left
    servers.remove(1).shutdown();
    let blank = StorageNode::new(demo.chunk_tokens);
    let rejoined = StorageServer::spawn(&addrs[1], blank, ServerConfig::default())
        .expect("rebind freed port");
    servers.insert(1, rejoined);

    let router =
        ShardRouter::connect_replicated(&addrs, Placement::RoundRobin, 2).expect("connect");
    let scanner = RepairScanner::new(router)
        .with_retry(RetryPolicy { max_busy_retries: 6, min_backoff_ms: 2, max_backoff_ms: 20 });
    let report = scanner.repair(&demo.hashes);
    assert!(report.busy_retries >= 2, "the forced refusals must be absorbed by backoff");
    assert!(report.converged(), "failed: {:?}", report.failed);
    assert!(scanner.scan(&demo.hashes).healthy());
    for s in servers {
        s.shutdown();
    }
}
