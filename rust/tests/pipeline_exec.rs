//! Integration tests of the pipelined fetch path behind the `Fetcher`
//! facade: the threaded executor against the analytic stage model, the
//! no-overlap serialized baseline, and the backpressure / cancellation
//! contracts. All timings here are *virtual* (simulation seconds), so
//! every assertion is deterministic regardless of host scheduling.

use std::time::Duration;

use kvfetcher::asic::{h20_table, DecodePool};
use kvfetcher::baselines::SystemProfile;
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::engine::ExecMode;
use kvfetcher::fetcher::{
    serialized_fetch, FetchConfig, FetchError, FetchRequest, Fetcher, PipelineConfig,
};
use kvfetcher::net::{BandwidthEstimator, BandwidthTrace, NetLink};

fn fetcher(profile: SystemProfile, trace: BandwidthTrace) -> Fetcher {
    Fetcher::builder()
        .profile(profile)
        .bandwidth(trace)
        .decode_pool(DecodePool::new(7, h20_table()))
        .build()
}

/// The tentpole determinism contract: for every system profile and
/// bandwidth regime, the threaded executor's timeline equals the
/// analytic planner's (same stage model, same order of operations) —
/// switched purely by the request's [`ExecMode`].
#[test]
fn executor_equals_analytic_across_profiles_and_bandwidths() {
    let raw = 100_000 * 245_760usize;
    let dev = DeviceSpec::h20();
    let profiles = [
        SystemProfile::kvfetcher(),
        SystemProfile::cachegen(&dev),
        SystemProfile::shadowserve(),
        SystemProfile::raw_reuse(),
        SystemProfile::llm265(),
    ];
    let traces = [
        BandwidthTrace::constant(2.0),
        BandwidthTrace::constant(16.0),
        BandwidthTrace::fig17(),
        BandwidthTrace::jitter(11, 8.0, 2.0, 30.0, 0.5, 500.0),
    ];
    let req = FetchRequest::new(100_000, raw);
    for profile in &profiles {
        for trace in &traces {
            let mut a = fetcher(profile.clone(), trace.clone());
            let analytic = a.run(&req).unwrap();
            let mut p = a.fresh();
            let pipelined = p.run(&req.clone().exec(ExecMode::Pipelined)).unwrap();
            assert!(!pipelined.aborted);
            assert_eq!(
                pipelined.plan.chunks.len(),
                analytic.plan.chunks.len(),
                "{}",
                profile.name
            );
            for (x, y) in analytic.plan.chunks.iter().zip(pipelined.plan.chunks.iter()) {
                assert_eq!(x.res_idx, y.res_idx, "{}", profile.name);
                assert_eq!(x.wire_bytes, y.wire_bytes, "{}", profile.name);
                assert!((x.trans_end - y.trans_end).abs() < 1e-9, "{}", profile.name);
                assert!((x.dec_start - y.dec_start).abs() < 1e-9, "{}", profile.name);
                assert!((x.dec_end - y.dec_end).abs() < 1e-9, "{}", profile.name);
            }
            assert!(
                (analytic.done_at() - pipelined.done_at()).abs() < 1e-9,
                "{}: analytic {:.6} vs pipelined {:.6}",
                profile.name,
                analytic.done_at(),
                pipelined.done_at()
            );
            // both runs left the shared link in the same state
            assert!((a.link().busy_until() - p.link().busy_until()).abs() < 1e-9);
        }
    }
}

/// Satellite acceptance: on a fixed bandwidth trace, the pipelined
/// executor's TTFT is <= (and on bandwidth-limited traces strictly
/// below) a no-overlap serial schedule of the same chunks.
#[test]
fn pipelined_ttft_beats_serialized_schedule() {
    let profile = SystemProfile::kvfetcher();
    let cfg = FetchConfig::default();
    let raw = 100_000 * 524_288usize; // LWM-7B-sized prefix
    for gbps in [1.0, 4.0, 8.0] {
        let mut f = fetcher(profile.clone(), BandwidthTrace::constant(gbps));
        let pipelined =
            f.run(&FetchRequest::new(100_000, raw).exec(ExecMode::Pipelined)).unwrap().plan;
        let mut link = NetLink::new(BandwidthTrace::constant(gbps));
        let mut pool = DecodePool::new(7, h20_table());
        let mut est = BandwidthEstimator::new(0.5);
        let serial =
            serialized_fetch(0.0, 100_000, raw, &profile, &cfg, &mut link, &mut pool, &mut est);
        assert!(
            pipelined.done_at < serial.done_at,
            "{gbps} Gbps: pipelined {:.3}s must strictly beat serialized {:.3}s",
            pipelined.done_at,
            serial.done_at
        );
        // overlap really happened: decode of chunk i overlaps transmit i+1
        for w in pipelined.chunks.windows(2) {
            assert!(w[1].trans_start <= w[0].dec_end + 1e-9);
        }
    }
}

/// Satellite acceptance: a slow decode stage backpressures the transmit
/// stage through the bounded channel, so staged-bitstream memory stays
/// O(queue_depth) chunks no matter how long the prefix is — and the
/// wall-clock stall never changes the virtual timeline. The depth comes
/// straight off the request.
#[test]
fn slow_decode_stage_bounds_transmit_queue_memory() {
    let profile = SystemProfile::kvfetcher();
    let tokens = 160_000usize; // 16 chunks
    let raw = tokens * 245_760;
    let depth = 2usize;
    let mut throttled = Fetcher::builder()
        .profile(profile.clone())
        .bandwidth(BandwidthTrace::constant(8.0))
        .decode_pool(DecodePool::new(7, h20_table()))
        .pipeline(PipelineConfig {
            queue_depth: 4,
            decode_throttle: Some(Duration::from_millis(5)),
        })
        .build();
    let req = FetchRequest::new(tokens, raw).exec(ExecMode::Pipelined).queue_depth(depth);
    let out = throttled.run(&req).unwrap();
    assert!(!out.aborted);
    assert_eq!(out.chunks_completed, 16);

    // at most queue_depth buffered + 1 in the decoder's hand + 1 being
    // produced can be staged at once
    let geo_raw_per_chunk = raw / 16;
    let max_chunk_wire = profile.wire_bytes(geo_raw_per_chunk); // 1080p upper bound
    let bound = (depth + 2) * max_chunk_wire;
    assert!(
        out.peak_inflight_wire_bytes <= bound,
        "peak staged bitstream {} exceeds bound {} ({} chunks deep)",
        out.peak_inflight_wire_bytes,
        bound,
        depth + 2
    );
    assert!(out.peak_inflight_wire_bytes > 0);

    // the throttle slows the wall clock, never the simulated clock
    let mut plain = fetcher(profile, BandwidthTrace::constant(8.0));
    let unthrottled =
        plain.run(&FetchRequest::new(tokens, raw).exec(ExecMode::Pipelined)).unwrap();
    assert!((out.done_at() - unthrottled.done_at()).abs() < 1e-9);
}

/// The abort path: cancelling a spawned session stops the stages at a
/// chunk boundary, drains the channels, reports `FetchError::Cancelled`,
/// and keeps the partial report.
#[test]
fn cancel_aborts_spawned_session_cleanly() {
    let raw = 100_000 * 245_760usize; // 10 chunks
    let f = Fetcher::builder()
        .profile(SystemProfile::kvfetcher())
        .bandwidth(BandwidthTrace::constant(8.0))
        .decode_pool(DecodePool::new(7, h20_table()))
        .pipeline(PipelineConfig {
            queue_depth: 1,
            decode_throttle: Some(Duration::from_millis(100)),
        })
        .build();
    let job = f.session(FetchRequest::new(100_000, raw).exec(ExecMode::Pipelined)).spawn();
    std::thread::sleep(Duration::from_millis(150));
    job.cancel();
    let (mut session, result) = job.join();
    let completed = match result {
        Err(FetchError::Cancelled { chunks_completed }) => chunks_completed,
        other => panic!("expected Cancelled, got {other:?}"),
    };
    let report = session.take_report().expect("partial report survives the abort");
    assert!(report.aborted);
    assert!(completed < 10, "{completed} chunks got through");
    assert_eq!(report.chunks_completed, completed);
    assert_eq!(report.plan.chunks.len(), completed);
    // the link reflects only what was actually transmitted
    let fetcher = session.into_fetcher();
    assert!(fetcher.link().bytes_sent > 0);
}

/// End-to-end: the facade's single-request TTFT primitive agrees
/// between modes across the Fig. 18 grid's device/model pairs.
#[test]
fn single_request_ttft_agrees_between_exec_modes() {
    for dev in [DeviceSpec::a100(), DeviceSpec::h20(), DeviceSpec::l20()] {
        for model in [ModelSpec::lwm_7b(), ModelSpec::yi_34b()] {
            let perf = PerfModel::new(dev.clone(), model);
            let f = Fetcher::builder()
                .profile(SystemProfile::kvfetcher())
                .bandwidth(BandwidthTrace::constant(16.0))
                .for_perf(&perf)
                .build();
            let ctx = 100_000;
            let reusable = 95_000;
            let at = f.ttft(&perf, ctx, reusable, ExecMode::Analytic).total();
            let pt = f.ttft(&perf, ctx, reusable, ExecMode::Pipelined).total();
            assert!(
                (at - pt).abs() <= 0.05 * at,
                "{} {}: analytic {:.4}s vs pipelined {:.4}s",
                dev.name,
                perf.model.name,
                at,
                pt
            );
        }
    }
}
