//! Integration tests of the threaded pipelined fetch executor
//! (`fetcher::executor`) against the analytic stage model, the
//! no-overlap serialized baseline, and its backpressure / cancellation
//! contracts. All timings here are *virtual* (simulation seconds), so
//! every assertion is deterministic regardless of host scheduling.

use std::time::Duration;

use kvfetcher::asic::{h20_table, DecodePool};
use kvfetcher::baselines::SystemProfile;
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::engine::{single_request_ttft, single_request_ttft_exec, ExecMode};
use kvfetcher::fetcher::{
    execute_fetch, plan_fetch, serialized_fetch, spawn_fetch, CancelToken, FetchConfig,
    FetchParams, PipelineConfig,
};
use kvfetcher::net::{BandwidthEstimator, BandwidthTrace, NetLink};

fn setup(trace: BandwidthTrace) -> (NetLink, DecodePool, BandwidthEstimator) {
    (NetLink::new(trace), DecodePool::new(7, h20_table()), BandwidthEstimator::new(0.5))
}

fn params(profile: SystemProfile, tokens: usize, raw: usize) -> FetchParams {
    FetchParams {
        now: 0.0,
        reusable_tokens: tokens,
        raw_bytes_total: raw,
        profile,
        cfg: FetchConfig::default(),
    }
}

/// The tentpole determinism contract: for every system profile and
/// bandwidth regime, the threaded executor's timeline equals the
/// analytic planner's (same stage model, same order of operations).
#[test]
fn executor_equals_analytic_across_profiles_and_bandwidths() {
    let raw = 100_000 * 245_760usize;
    let dev = DeviceSpec::h20();
    let profiles = [
        SystemProfile::kvfetcher(),
        SystemProfile::cachegen(&dev),
        SystemProfile::shadowserve(),
        SystemProfile::raw_reuse(),
        SystemProfile::llm265(),
    ];
    let traces = [
        BandwidthTrace::constant(2.0),
        BandwidthTrace::constant(16.0),
        BandwidthTrace::fig17(),
        BandwidthTrace::jitter(11, 8.0, 2.0, 30.0, 0.5, 500.0),
    ];
    for profile in &profiles {
        for trace in &traces {
            let (mut l1, mut p1, mut e1) = setup(trace.clone());
            let analytic = plan_fetch(
                0.0,
                100_000,
                raw,
                profile,
                &FetchConfig::default(),
                &mut l1,
                &mut p1,
                &mut e1,
            );
            let (mut l2, mut p2, mut e2) = setup(trace.clone());
            let out = execute_fetch(
                &params(profile.clone(), 100_000, raw),
                &PipelineConfig::default(),
                &CancelToken::new(),
                &mut l2,
                &mut p2,
                &mut e2,
            );
            assert!(!out.aborted);
            assert_eq!(out.plan.chunks.len(), analytic.chunks.len(), "{}", profile.name);
            for (a, b) in analytic.chunks.iter().zip(out.plan.chunks.iter()) {
                assert_eq!(a.res_idx, b.res_idx, "{}", profile.name);
                assert_eq!(a.wire_bytes, b.wire_bytes, "{}", profile.name);
                assert!((a.trans_end - b.trans_end).abs() < 1e-9, "{}", profile.name);
                assert!((a.dec_start - b.dec_start).abs() < 1e-9, "{}", profile.name);
                assert!((a.dec_end - b.dec_end).abs() < 1e-9, "{}", profile.name);
            }
            assert!(
                (analytic.done_at - out.plan.done_at).abs() < 1e-9,
                "{}: analytic {:.6} vs pipelined {:.6}",
                profile.name,
                analytic.done_at,
                out.plan.done_at
            );
            assert!((l1.busy_until() - l2.busy_until()).abs() < 1e-9);
        }
    }
}

/// Satellite acceptance: on a fixed bandwidth trace, the pipelined
/// executor's TTFT is <= (and on bandwidth-limited traces strictly
/// below) a no-overlap serial schedule of the same chunks.
#[test]
fn pipelined_ttft_beats_serialized_schedule() {
    let profile = SystemProfile::kvfetcher();
    let cfg = FetchConfig::default();
    let raw = 100_000 * 524_288usize; // LWM-7B-sized prefix
    for gbps in [1.0, 4.0, 8.0] {
        let (mut l1, mut p1, mut e1) = setup(BandwidthTrace::constant(gbps));
        let pipelined = execute_fetch(
            &params(profile.clone(), 100_000, raw),
            &PipelineConfig::default(),
            &CancelToken::new(),
            &mut l1,
            &mut p1,
            &mut e1,
        )
        .plan;
        let (mut l2, mut p2, mut e2) = setup(BandwidthTrace::constant(gbps));
        let serial = serialized_fetch(0.0, 100_000, raw, &profile, &cfg, &mut l2, &mut p2, &mut e2);
        assert!(
            pipelined.done_at < serial.done_at,
            "{gbps} Gbps: pipelined {:.3}s must strictly beat serialized {:.3}s",
            pipelined.done_at,
            serial.done_at
        );
        // overlap really happened: decode of chunk i overlaps transmit i+1
        for w in pipelined.chunks.windows(2) {
            assert!(w[1].trans_start <= w[0].dec_end + 1e-9);
        }
    }
}

/// Satellite acceptance: a slow decode stage backpressures the transmit
/// stage through the bounded channel, so staged-bitstream memory stays
/// O(queue_depth) chunks no matter how long the prefix is — and the
/// wall-clock stall never changes the virtual timeline.
#[test]
fn slow_decode_stage_bounds_transmit_queue_memory() {
    let profile = SystemProfile::kvfetcher();
    let tokens = 160_000usize; // 16 chunks
    let raw = tokens * 245_760;
    let depth = 2usize;
    let pipe = PipelineConfig {
        queue_depth: depth,
        decode_throttle: Some(Duration::from_millis(5)),
    };
    let (mut l1, mut p1, mut e1) = setup(BandwidthTrace::constant(8.0));
    let out = execute_fetch(
        &params(profile.clone(), tokens, raw),
        &pipe,
        &CancelToken::new(),
        &mut l1,
        &mut p1,
        &mut e1,
    );
    assert!(!out.aborted);
    assert_eq!(out.chunks_completed, 16);

    // at most queue_depth buffered + 1 in the decoder's hand + 1 being
    // produced can be staged at once
    let geo_raw_per_chunk = raw / 16;
    let max_chunk_wire = profile.wire_bytes(geo_raw_per_chunk); // 1080p upper bound
    let bound = (depth + 2) * max_chunk_wire;
    assert!(
        out.peak_inflight_wire_bytes <= bound,
        "peak staged bitstream {} exceeds bound {} ({} chunks deep)",
        out.peak_inflight_wire_bytes,
        bound,
        depth + 2
    );
    assert!(out.peak_inflight_wire_bytes > 0);

    // the throttle slows the wall clock, never the simulated clock
    let (mut l2, mut p2, mut e2) = setup(BandwidthTrace::constant(8.0));
    let unthrottled = execute_fetch(
        &params(profile, tokens, raw),
        &PipelineConfig::default(),
        &CancelToken::new(),
        &mut l2,
        &mut p2,
        &mut e2,
    );
    assert!((out.plan.done_at - unthrottled.plan.done_at).abs() < 1e-9);
}

/// The abort path: cancelling a spawned fetch stops the stages at a
/// chunk boundary, drains the channels, and reports a partial plan.
#[test]
fn cancel_aborts_spawned_fetch_cleanly() {
    let profile = SystemProfile::kvfetcher();
    let raw = 100_000 * 245_760usize; // 10 chunks
    let pipe = PipelineConfig {
        queue_depth: 1,
        decode_throttle: Some(Duration::from_millis(100)),
    };
    let (link, pool, est) = setup(BandwidthTrace::constant(8.0));
    let job = spawn_fetch(params(profile, 100_000, raw), pipe, link, pool, est);
    std::thread::sleep(Duration::from_millis(150));
    job.cancel();
    let (out, link_back, _pool_back, _est_back) = job.join();
    assert!(out.aborted);
    assert!(out.chunks_completed < 10, "{} chunks got through", out.chunks_completed);
    assert_eq!(out.plan.chunks.len(), out.chunks_completed);
    // the link reflects only what was actually transmitted
    let sent: usize = link_back.bytes_sent;
    assert!(sent > 0);
}

/// End-to-end: the engine-facing single-request TTFT primitive agrees
/// between modes across the Fig. 18 grid's device/model pairs.
#[test]
fn single_request_ttft_agrees_between_exec_modes() {
    let cfg = FetchConfig::default();
    let bw = BandwidthTrace::constant(16.0);
    for dev in [DeviceSpec::a100(), DeviceSpec::h20(), DeviceSpec::l20()] {
        for model in [ModelSpec::lwm_7b(), ModelSpec::yi_34b()] {
            let perf = PerfModel::new(dev.clone(), model);
            let ctx = 100_000;
            let reusable = 95_000;
            let a = single_request_ttft(&perf, &SystemProfile::kvfetcher(), &cfg, &bw, ctx, reusable);
            let p = single_request_ttft_exec(
                &perf,
                &SystemProfile::kvfetcher(),
                &cfg,
                &bw,
                ctx,
                reusable,
                ExecMode::Pipelined,
            );
            let (at, pt) = (a.total(), p.total());
            assert!(
                (at - pt).abs() <= 0.05 * at,
                "{} {}: analytic {:.4}s vs pipelined {:.4}s",
                dev.name,
                perf.model.name,
                at,
                pt
            );
        }
    }
}
