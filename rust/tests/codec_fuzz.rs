//! Decoder robustness: the video decoder, entropy decoder, the CAS
//! wire parsers (ISSUE 8), and the wire-v5 service protocol parsers
//! (ISSUE 10) parse bytes that arrive over the network or from disk —
//! they must *never* panic, whatever the input. Random inputs,
//! truncations, and single-byte corruptions of valid streams must all
//! return Ok or a typed Err.

use std::io::Cursor;

use kvfetcher::cas::object::{decode_object, encode_object};
use kvfetcher::cas::{Digest, Manifest, ManifestChunk, ObjectRef};
use kvfetcher::codec::{decode_video, encode_video, rans, CodecConfig, Frame};
use kvfetcher::fetcher::ChunkPayload;
use kvfetcher::service::protocol::{
    decode_request, decode_response, encode_request, encode_response, frame_bytes, read_frame,
    validate_frame_len, FrameRead, MAX_FRAME_BYTES,
};
use kvfetcher::service::{demo_prefix, NodeStats, Request, Response};
use kvfetcher::util::proptest::gen_bytes;
use kvfetcher::util::Prng;

fn valid_stream(seed: u64) -> Vec<u8> {
    let mut rng = Prng::new(seed);
    let mut frames = Vec::new();
    for _ in 0..3 {
        let mut f = Frame::new(16, 16);
        for p in 0..3 {
            for v in f.planes[p].iter_mut() {
                *v = rng.next_u64() as u8;
            }
        }
        frames.push(f);
    }
    let cfg = if seed % 2 == 0 { CodecConfig::lossless() } else { CodecConfig::lossy(12) };
    encode_video(&frames, &cfg, b"meta").0
}

#[test]
fn decode_never_panics_on_random_bytes() {
    let mut rng = Prng::new(1000);
    for case in 0..500 {
        let len = rng.below(4096) as usize;
        let data = gen_bytes(&mut rng, len, false);
        let _ = std::hint::black_box(decode_video(&data));
        let _ = std::hint::black_box(rans::decode(&data));
        let _ = case;
    }
}

#[test]
fn decode_never_panics_on_corrupted_streams() {
    let mut rng = Prng::new(2000);
    for seed in 0..20u64 {
        let valid = valid_stream(seed);
        // sanity: the unmodified stream decodes
        decode_video(&valid).expect("valid stream must decode");
        // single-byte corruptions
        for _ in 0..60 {
            let mut bad = valid.clone();
            let i = rng.below(bad.len() as u64) as usize;
            bad[i] ^= 1 << rng.below(8);
            let _ = std::hint::black_box(decode_video(&bad));
        }
        // truncations
        for _ in 0..20 {
            let cut = rng.below(valid.len() as u64) as usize;
            let _ = std::hint::black_box(decode_video(&valid[..cut]));
        }
        // extensions with junk
        let mut ext = valid.clone();
        ext.extend(gen_bytes(&mut rng, 64, false));
        let _ = std::hint::black_box(decode_video(&ext));
    }
}

#[test]
fn cas_parsers_never_panic_on_random_bytes() {
    let mut rng = Prng::new(4000);
    for _ in 0..500 {
        let len = rng.below(2048) as usize;
        let data = gen_bytes(&mut rng, len, false);
        let _ = std::hint::black_box(Manifest::decode(&data));
        let _ = std::hint::black_box(decode_object(&data));
    }
}

#[test]
fn cas_parsers_never_panic_on_corrupted_streams() {
    let mut rng = Prng::new(5000);
    let object = encode_object(&[1.0, 0.5], &[vec![1, 2, 3], vec![4, 5]]);
    decode_object(&object).expect("valid object must decode");
    let manifest = Manifest {
        chunk_tokens: 32,
        resolutions: vec!["144p".into(), "240p".into()],
        chunks: (0..3u64)
            .map(|i| ManifestChunk {
                hash: 0x1000 + i,
                tokens: 32,
                objects: vec![
                    ObjectRef { key: Digest::of(&[i as u8]), bytes: 10 },
                    ObjectRef { key: Digest::of(&[i as u8, 1]), bytes: 11 },
                ],
            })
            .collect(),
    }
    .encode();
    Manifest::decode(&manifest).expect("valid manifest must decode");
    // each parser also sees the *other* format's bytes: cross-feeding
    // must fail typed, never panic
    for valid in [object, manifest] {
        for _ in 0..200 {
            let mut bad = valid.clone();
            let i = rng.below(bad.len() as u64) as usize;
            bad[i] ^= 1 << rng.below(8);
            let _ = std::hint::black_box(Manifest::decode(&bad));
            let _ = std::hint::black_box(decode_object(&bad));
        }
        for _ in 0..50 {
            let cut = rng.below(valid.len() as u64) as usize;
            let _ = std::hint::black_box(Manifest::decode(&valid[..cut]));
            let _ = std::hint::black_box(decode_object(&valid[..cut]));
        }
        let mut ext = valid.clone();
        ext.extend(gen_bytes(&mut rng, 64, false));
        let _ = std::hint::black_box(Manifest::decode(&ext));
        let _ = std::hint::black_box(decode_object(&ext));
    }
}

/// Representative valid frames of every wire-v5 message kind, as
/// `(tag, payload)` pairs straight from the canonical encoders.
fn wire_corpus() -> (Vec<(u8, Vec<u8>)>, Vec<(u8, Vec<u8>)>) {
    let demo = demo_prefix(3, 2, 24);
    let chunk = demo.chunks[0].clone();
    let variant = chunk.variants[1].clone();
    let requests = vec![
        Request::LookupPrefix { tokens: demo.tokens.clone() },
        Request::HasChunks { hashes: demo.hashes.clone() },
        Request::FetchChunk { hash: demo.hashes[0], resolution: "240p".into() },
        Request::PullChunk { hash: demo.hashes[1] },
        Request::PutChunk { chunk: chunk.clone() },
        Request::Stats,
    ];
    let responses = vec![
        Response::PrefixMatch { hashes: demo.hashes.clone() },
        Response::Has { present: vec![true, false] },
        Response::Chunk(ChunkPayload {
            hash: chunk.hash,
            tokens: chunk.tokens,
            resolution: "240p".into(),
            scales: chunk.scales.clone(),
            group_bytes: variant.group_bytes,
        }),
        Response::NotFound { hash: 0xDEAD },
        Response::Stored { stored: true, evicted: 3 },
        Response::Stats(NodeStats {
            chunks: 7,
            used_bytes: 123_456,
            capacity_bytes: Some(1 << 20),
            evictions: 2,
            inflight_bytes: 64,
            peak_inflight_bytes: 4096,
            busy_replies: 5,
            served_bytes: 1 << 22,
            map_version: 9,
        }),
        Response::Err { msg: "no such variant".into() },
        Response::Busy { retry_after_ms: 25 },
        Response::ChunkFull(chunk),
    ];
    (
        requests.iter().map(encode_request).collect(),
        responses.iter().map(encode_response).collect(),
    )
}

#[test]
fn wire_parsers_never_panic_on_random_payloads() {
    let mut rng = Prng::new(6000);
    for _ in 0..600 {
        let tag = rng.below(256) as u8;
        let len = rng.below(2048) as usize;
        let data = gen_bytes(&mut rng, len, false);
        let _ = std::hint::black_box(decode_request(tag, &data));
        let _ = std::hint::black_box(decode_response(tag, &data));
    }
}

#[test]
fn wire_messages_round_trip_and_reject_cross_fed_tags() {
    let demo = demo_prefix(3, 2, 24);
    let chunk = demo.chunks[0].clone();
    let requests = vec![
        Request::LookupPrefix { tokens: demo.tokens.clone() },
        Request::HasChunks { hashes: demo.hashes.clone() },
        Request::FetchChunk { hash: demo.hashes[0], resolution: "240p".into() },
        Request::PullChunk { hash: demo.hashes[1] },
        Request::PutChunk { chunk: chunk.clone() },
        Request::Stats,
    ];
    for req in &requests {
        let (tag, payload) = encode_request(req);
        let back = decode_request(tag, &payload).expect("valid request decodes");
        assert_eq!(&back, req);
        // a request tag is never a valid response tag
        assert!(decode_response(tag, &payload).is_err(), "cross-fed request tag {tag}");
    }
    let responses =
        vec![Response::Stats(NodeStats::default()), Response::ChunkFull(chunk)];
    for resp in &responses {
        let (tag, payload) = encode_response(resp);
        let back = decode_response(tag, &payload).expect("valid response decodes");
        assert_eq!(&back, resp);
        assert!(decode_request(tag, &payload).is_err(), "cross-fed response tag {tag}");
    }
}

#[test]
fn wire_parsers_never_panic_on_corrupted_frames() {
    let mut rng = Prng::new(7000);
    let (requests, responses) = wire_corpus();
    for (tag, payload) in requests.iter().chain(&responses) {
        // sanity: one of the two decoders accepts the pristine frame
        let pristine_ok = decode_request(*tag, payload).is_ok()
            || decode_response(*tag, payload).is_ok();
        assert!(pristine_ok, "tag {tag}: pristine frame must decode");
        // single-bit corruptions — possibly still valid, never a panic
        for _ in 0..60 {
            let mut bad = payload.clone();
            if bad.is_empty() {
                break;
            }
            let i = rng.below(bad.len() as u64) as usize;
            bad[i] ^= 1 << rng.below(8);
            let _ = std::hint::black_box(decode_request(*tag, &bad));
            let _ = std::hint::black_box(decode_response(*tag, &bad));
        }
        // truncations
        for _ in 0..20 {
            let cut = rng.below((payload.len() + 1) as u64) as usize;
            let _ = std::hint::black_box(decode_request(*tag, &payload[..cut]));
            let _ = std::hint::black_box(decode_response(*tag, &payload[..cut]));
        }
        // trailing junk: the deframer hands the parser an exact
        // payload, so leftover bytes are a framing bug — both decoders
        // must refuse them (`rd.finish()`), typed, never a panic
        let mut ext = payload.clone();
        ext.extend(gen_bytes(&mut rng, 32, false));
        assert!(decode_request(*tag, &ext).is_err(), "tag {tag}: junk tail must not decode");
        assert!(decode_response(*tag, &ext).is_err(), "tag {tag}: junk tail must not decode");
    }
}

#[test]
fn frame_layer_never_panics_and_gates_lengths() {
    // length gate edges
    assert!(validate_frame_len(0).is_err(), "zero-length frames are malformed");
    assert!(validate_frame_len(1).is_ok());
    assert!(validate_frame_len(MAX_FRAME_BYTES).is_ok());
    assert!(validate_frame_len(MAX_FRAME_BYTES + 1).is_err(), "capacity refusal");

    // a declared length past the cap must be refused before the
    // payload allocation, whatever bytes follow
    let mut huge = u32::MAX.to_le_bytes().to_vec();
    huge.extend_from_slice(&[0u8; 16]);
    assert!(read_frame(&mut Cursor::new(huge)).is_err());
    let zero = 0u32.to_le_bytes().to_vec();
    assert!(read_frame(&mut Cursor::new(zero)).is_err());

    // a valid frame round-trips through the deframer...
    let (tag, payload) = encode_request(&Request::PullChunk { hash: 77 });
    let framed = frame_bytes(tag, &payload);
    match read_frame(&mut Cursor::new(framed.clone())).expect("frame reads") {
        FrameRead::Frame(t, p) => {
            assert_eq!(t, tag);
            assert_eq!(p, payload);
        }
        other => panic!("expected a frame, got {other:?}"),
    }
    // ...every truncation of it is Eof or a typed error, never a panic
    for cut in 0..framed.len() {
        let _ = std::hint::black_box(read_frame(&mut Cursor::new(framed[..cut].to_vec())));
    }
    // random byte streams with a bounded declared length (the first
    // four bytes are the length header; keep it small so a fuzz case
    // never legitimately allocates a quarter-gigabyte payload)
    let mut rng = Prng::new(8000);
    for _ in 0..300 {
        let len = rng.below(64) as usize;
        let mut data = gen_bytes(&mut rng, len, false);
        if data.len() >= 4 {
            data[2] = 0;
            data[3] = 0;
        }
        let _ = std::hint::black_box(read_frame(&mut Cursor::new(data)));
    }
}

#[test]
fn layout_meta_never_panics() {
    let mut rng = Prng::new(3000);
    for _ in 0..300 {
        let len = rng.below(128) as usize;
        let data = gen_bytes(&mut rng, len, false);
        let _ = std::hint::black_box(kvfetcher::layout::InterLayout::from_meta(&data));
    }
}
