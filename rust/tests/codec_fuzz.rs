//! Decoder robustness: the video decoder, entropy decoder, and the
//! CAS wire parsers (ISSUE 8) parse bytes that arrive over the network
//! or from disk — they must *never* panic, whatever the input. Random
//! inputs, truncations, and single-byte corruptions of valid streams
//! must all return Ok or Err.

use kvfetcher::cas::object::{decode_object, encode_object};
use kvfetcher::cas::{Digest, Manifest, ManifestChunk, ObjectRef};
use kvfetcher::codec::{decode_video, encode_video, rans, CodecConfig, Frame};
use kvfetcher::util::proptest::gen_bytes;
use kvfetcher::util::Prng;

fn valid_stream(seed: u64) -> Vec<u8> {
    let mut rng = Prng::new(seed);
    let mut frames = Vec::new();
    for _ in 0..3 {
        let mut f = Frame::new(16, 16);
        for p in 0..3 {
            for v in f.planes[p].iter_mut() {
                *v = rng.next_u64() as u8;
            }
        }
        frames.push(f);
    }
    let cfg = if seed % 2 == 0 { CodecConfig::lossless() } else { CodecConfig::lossy(12) };
    encode_video(&frames, &cfg, b"meta").0
}

#[test]
fn decode_never_panics_on_random_bytes() {
    let mut rng = Prng::new(1000);
    for case in 0..500 {
        let len = rng.below(4096) as usize;
        let data = gen_bytes(&mut rng, len, false);
        let _ = std::hint::black_box(decode_video(&data));
        let _ = std::hint::black_box(rans::decode(&data));
        let _ = case;
    }
}

#[test]
fn decode_never_panics_on_corrupted_streams() {
    let mut rng = Prng::new(2000);
    for seed in 0..20u64 {
        let valid = valid_stream(seed);
        // sanity: the unmodified stream decodes
        decode_video(&valid).expect("valid stream must decode");
        // single-byte corruptions
        for _ in 0..60 {
            let mut bad = valid.clone();
            let i = rng.below(bad.len() as u64) as usize;
            bad[i] ^= 1 << rng.below(8);
            let _ = std::hint::black_box(decode_video(&bad));
        }
        // truncations
        for _ in 0..20 {
            let cut = rng.below(valid.len() as u64) as usize;
            let _ = std::hint::black_box(decode_video(&valid[..cut]));
        }
        // extensions with junk
        let mut ext = valid.clone();
        ext.extend(gen_bytes(&mut rng, 64, false));
        let _ = std::hint::black_box(decode_video(&ext));
    }
}

#[test]
fn cas_parsers_never_panic_on_random_bytes() {
    let mut rng = Prng::new(4000);
    for _ in 0..500 {
        let len = rng.below(2048) as usize;
        let data = gen_bytes(&mut rng, len, false);
        let _ = std::hint::black_box(Manifest::decode(&data));
        let _ = std::hint::black_box(decode_object(&data));
    }
}

#[test]
fn cas_parsers_never_panic_on_corrupted_streams() {
    let mut rng = Prng::new(5000);
    let object = encode_object(&[1.0, 0.5], &[vec![1, 2, 3], vec![4, 5]]);
    decode_object(&object).expect("valid object must decode");
    let manifest = Manifest {
        chunk_tokens: 32,
        resolutions: vec!["144p".into(), "240p".into()],
        chunks: (0..3u64)
            .map(|i| ManifestChunk {
                hash: 0x1000 + i,
                tokens: 32,
                objects: vec![
                    ObjectRef { key: Digest::of(&[i as u8]), bytes: 10 },
                    ObjectRef { key: Digest::of(&[i as u8, 1]), bytes: 11 },
                ],
            })
            .collect(),
    }
    .encode();
    Manifest::decode(&manifest).expect("valid manifest must decode");
    // each parser also sees the *other* format's bytes: cross-feeding
    // must fail typed, never panic
    for valid in [object, manifest] {
        for _ in 0..200 {
            let mut bad = valid.clone();
            let i = rng.below(bad.len() as u64) as usize;
            bad[i] ^= 1 << rng.below(8);
            let _ = std::hint::black_box(Manifest::decode(&bad));
            let _ = std::hint::black_box(decode_object(&bad));
        }
        for _ in 0..50 {
            let cut = rng.below(valid.len() as u64) as usize;
            let _ = std::hint::black_box(Manifest::decode(&valid[..cut]));
            let _ = std::hint::black_box(decode_object(&valid[..cut]));
        }
        let mut ext = valid.clone();
        ext.extend(gen_bytes(&mut rng, 64, false));
        let _ = std::hint::black_box(Manifest::decode(&ext));
        let _ = std::hint::black_box(decode_object(&ext));
    }
}

#[test]
fn layout_meta_never_panics() {
    let mut rng = Prng::new(3000);
    for _ in 0..300 {
        let len = rng.below(128) as usize;
        let data = gen_bytes(&mut rng, len, false);
        let _ = std::hint::black_box(kvfetcher::layout::InterLayout::from_meta(&data));
    }
}
