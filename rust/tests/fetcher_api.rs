//! Contract tests of the unified `Fetcher` facade (ISSUE 3):
//!
//! * builder default/override matrix — the facade reproduces exactly
//!   what hand-threaded state produced;
//! * `FetchError` variant mapping from wire faults (truncated frame,
//!   oversized frame, decode mismatch, busy admission refusals) and
//!   dead shards.
//!
//! (The ISSUE 3 deprecated-shim equivalence tests left with the shims
//! themselves — `execute_fetch*` / `spawn_fetch` /
//! `single_request_ttft*` are deleted, and the facade paths they were
//! checked against are covered directly here and in
//! `tests/pipeline_exec.rs`.)

use std::sync::{Arc, Mutex};

use kvfetcher::asic::{h20_table, DecodePool};
use kvfetcher::baselines::SystemProfile;
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::codec::CodecConfig;
use kvfetcher::engine::ExecMode;
use kvfetcher::fetcher::transport::decode_payload;
use kvfetcher::fetcher::{
    plan_fetch, ChunkPayload, FetchConfig, FetchError, FetchRequest, Fetcher, ResolutionPolicy,
};
use kvfetcher::kvstore::StorageNode;
use kvfetcher::layout::{self, IntraLayout, Resolution};
use kvfetcher::net::{BandwidthEstimator, BandwidthTrace, NetLink};
use kvfetcher::quant::quantize;
use kvfetcher::service::{
    demo_prefix, protocol, Backend, Request, ServerConfig, SourceRegistry, SourceSpec,
    StorageServer, DEMO_LADDER,
};
use kvfetcher::tensor::KvCache;
use kvfetcher::util::Prng;

const RAW: usize = 100_000 * 245_760;

fn manual_plan(
    profile: &SystemProfile,
    cfg: &FetchConfig,
    gbps: f64,
    units: usize,
) -> kvfetcher::fetcher::FetchPlan {
    let mut link = NetLink::new(BandwidthTrace::constant(gbps));
    let mut pool = DecodePool::new(units, h20_table());
    let mut est = BandwidthEstimator::new(0.5);
    plan_fetch(0.0, 100_000, RAW, profile, cfg, &mut link, &mut pool, &mut est)
}

fn assert_plans_equal(a: &kvfetcher::fetcher::FetchPlan, b: &kvfetcher::fetcher::FetchPlan) {
    assert_eq!(a.chunks.len(), b.chunks.len());
    for (x, y) in a.chunks.iter().zip(&b.chunks) {
        assert_eq!(x.res_idx, y.res_idx);
        assert_eq!(x.wire_bytes, y.wire_bytes);
        assert!((x.trans_end - y.trans_end).abs() < 1e-12);
        assert!((x.dec_end - y.dec_end).abs() < 1e-12);
    }
    assert!((a.done_at - b.done_at).abs() < 1e-12);
}

// -------------------------------------------------- builder matrix

/// The builder's defaults are exactly the hand-threaded defaults every
/// call site used to repeat: kvfetcher profile, default fetch config,
/// 16 Gbps constant link, 7-unit H20 pool, 0.5-alpha estimator.
#[test]
fn builder_defaults_match_hand_threaded_state() {
    let mut f = Fetcher::builder().build();
    let report = f.run(&FetchRequest::new(100_000, RAW)).unwrap();
    let manual = manual_plan(&SystemProfile::kvfetcher(), &FetchConfig::default(), 16.0, 7);
    assert_plans_equal(&report.plan, &manual);
}

/// Every builder override lands: profile, fetch config, bandwidth,
/// decode pool, and the perf-model convenience.
#[test]
fn builder_overrides_land() {
    let dev = DeviceSpec::h20();
    // profile + bandwidth override
    let mut f = Fetcher::builder()
        .profile(SystemProfile::cachegen(&dev))
        .bandwidth_gbps(4.0)
        .build();
    let report = f.run(&FetchRequest::new(100_000, RAW)).unwrap();
    let manual = manual_plan(&SystemProfile::cachegen(&dev), &FetchConfig::default(), 4.0, 7);
    assert_plans_equal(&report.plan, &manual);

    // fetch-config override: halving chunk_tokens doubles the chunks
    let cfg = FetchConfig { chunk_tokens: 5_000, ..Default::default() };
    let mut f = Fetcher::builder().fetch_config(cfg.clone()).build();
    assert_eq!(f.run(&FetchRequest::new(100_000, RAW)).unwrap().plan.chunks.len(), 20);

    // decode-pool override via for_perf sizes like the engine
    let perf = PerfModel::new(DeviceSpec::l20(), ModelSpec::lwm_7b());
    let units = perf.dev.nvdecs * perf.n_gpus;
    let mut f = Fetcher::builder().bandwidth_gbps(16.0).for_perf(&perf).build();
    let got = f.run(&FetchRequest::new(100_000, RAW)).unwrap();
    let mut link = NetLink::new(BandwidthTrace::constant(16.0));
    let mut pool = DecodePool::new(units, perf.dev.decode_table());
    let mut est = BandwidthEstimator::new(0.5);
    let manual = plan_fetch(
        0.0,
        100_000,
        RAW,
        &SystemProfile::kvfetcher(),
        &FetchConfig::default(),
        &mut link,
        &mut pool,
        &mut est,
    );
    assert_plans_equal(&got.plan, &manual);
}

/// Request-level overrides beat the builder's config without mutating
/// it: resolution policy and queue depth are per-request.
#[test]
fn request_overrides_do_not_mutate_the_fetcher() {
    let f = Fetcher::builder().bandwidth_gbps(4.0).build();
    let mut a = f.fresh();
    let r1 = a
        .run(&FetchRequest::new(100_000, RAW).resolution(ResolutionPolicy::Fixed(1)))
        .unwrap();
    assert!(r1.plan.chunks.iter().all(|c| c.res_idx == 1));
    // the fetcher's own config is untouched: a fresh run re-adapts
    assert!(a.config().adaptive);
    let mut b = f.fresh();
    let adaptive = b.run(&FetchRequest::new(100_000, RAW)).unwrap();
    let manual = manual_plan(&SystemProfile::kvfetcher(), &FetchConfig::default(), 4.0, 7);
    assert_plans_equal(&adaptive.plan, &manual);
}

/// Consecutive runs through one fetcher contend on the shared link —
/// the facade keeps the engine's contention semantics.
#[test]
fn consecutive_runs_contend_on_shared_state() {
    let mut f = Fetcher::builder().bandwidth_gbps(8.0).build();
    let first = f.run(&FetchRequest::new(50_000, RAW / 2)).unwrap();
    let second = f.run(&FetchRequest::new(50_000, RAW / 2)).unwrap();
    assert!(
        second.plan.chunks[0].trans_start >= first.plan.chunks.last().unwrap().trans_end - 1e-9,
        "second fetch must queue behind the first on the FIFO link"
    );
    // a reset clears the carry-over
    f.reset();
    let clean = f.run(&FetchRequest::new(50_000, RAW / 2)).unwrap();
    assert_plans_equal(&clean.plan, &first.plan);
}

// ------------------------------------------- wire-fault error mapping

/// Truncated frames surface as `FetchError::Decode` with the truncation
/// named, from both the payload parser and the chunk marshaling.
#[test]
fn truncated_frame_maps_to_decode_error() {
    // a string field cut short trips the truncation check itself
    let (tag, body) = protocol::encode_request(&Request::FetchChunk {
        hash: 7,
        resolution: "1080p".into(),
    });
    match protocol::decode_request(tag, &body[..body.len() - 3]) {
        Err(FetchError::Decode { detail, .. }) => {
            assert!(detail.contains("truncated"), "{detail}")
        }
        other => panic!("wrong result {:?}", other.err()),
    }
    // a truncated chunk body trips the count bound first — still Decode
    let demo = demo_prefix(3, 1, 32);
    let (tag, body) = protocol::encode_request(&Request::PutChunk {
        chunk: demo.chunks[0].clone(),
    });
    assert!(matches!(
        protocol::decode_request(tag, &body[..body.len() - 3]),
        Err(FetchError::Decode { .. })
    ));
}

/// Oversized frames are a capacity refusal before any allocation.
#[test]
fn oversized_frame_maps_to_capacity_error() {
    match protocol::validate_frame_len(protocol::MAX_FRAME_BYTES + 1) {
        Err(FetchError::Capacity { detail }) => {
            assert!(detail.contains("MAX_FRAME_BYTES"), "{detail}")
        }
        other => panic!("wrong result {:?}", other.err()),
    }
    assert!(protocol::validate_frame_len(0).is_err());
    assert!(protocol::validate_frame_len(1024).is_ok());
}

/// Payloads whose group streams decode but disagree on the chunk shape
/// map to `FetchError::Decode` (the codec-mismatch wire fault).
#[test]
fn decode_mismatch_maps_to_decode_error() {
    let res = Resolution { name: "tiny", w: 64, h: 32 };
    let intra = IntraLayout { hr: 2, hc: 4, dr: 8, dc: 4 };
    let mut rng = Prng::new(33);
    // same plane/head geometry, different token counts
    let big = quantize(&KvCache::synthetic(&mut rng, 48, 6, 8, 32, 0.9));
    let small = quantize(&KvCache::synthetic(&mut rng, 32, 6, 8, 32, 0.9));
    let g_big = layout::encode_chunk(&big, res, intra, &CodecConfig::lossless()).unwrap();
    let g_small = layout::encode_chunk(&small, res, intra, &CodecConfig::lossless()).unwrap();
    assert!(g_big.len() >= 2 && g_small.len() >= 2);
    let frankenstein = ChunkPayload {
        hash: 1,
        tokens: big.tokens,
        resolution: "tiny".into(),
        scales: big.scales.clone(),
        group_bytes: vec![g_big[0].bytes.clone(), g_small[1].bytes.clone()],
    };
    match decode_payload(&frankenstein) {
        Err(FetchError::Decode { detail, .. }) => {
            assert!(detail.contains("disagree"), "{detail}")
        }
        other => panic!("wrong result {:?}", other.err()),
    }
    // garbage bitstreams map to Decode too (via CodecError)
    let garbage = ChunkPayload {
        hash: 0,
        tokens: 0,
        resolution: "x".into(),
        scales: vec![],
        group_bytes: vec![vec![9, 9, 9]],
    };
    assert!(matches!(decode_payload(&garbage), Err(FetchError::Decode { .. })));
}

/// A dead shard in a live fleet is attributed by index and address
/// (the satellite fix: connect failures no longer fold into a generic
/// fetch error string).
#[test]
fn dead_shard_is_attributed_by_index_and_address() {
    let demo = demo_prefix(21, 2, 32);
    let server = StorageServer::spawn(
        "127.0.0.1:0",
        StorageNode::new(demo.chunk_tokens),
        ServerConfig::default(),
    )
    .expect("bind");
    let live = server.local_addr().to_string();
    let mut spec = SourceSpec::new(demo.hashes.clone(), DEMO_LADDER);
    spec.addrs = vec![live, "127.0.0.1:1".into()]; // shard 1 is dead
    match SourceRegistry::with_defaults().create(Backend::Tcp, &spec) {
        Err(FetchError::Connect { shard, addr, .. }) => {
            assert_eq!(shard, 1);
            assert_eq!(addr, "127.0.0.1:1");
        }
        other => panic!("wrong result {:?}", other.err()),
    }
    server.shutdown();
}

/// A sourced fetch that hits a missing chunk surfaces a typed transport
/// error naming the chunk, and the session keeps the partial report.
#[test]
fn missing_chunk_fails_the_session_with_a_transport_error() {
    let demo = demo_prefix(7, 4, 32);
    // register only the first two chunks
    let mut node = StorageNode::new(demo.chunk_tokens);
    for c in demo.chunks.iter().take(2) {
        node.register(c.clone());
    }
    let mut spec = SourceSpec::new(demo.hashes.clone(), DEMO_LADDER);
    spec.node = Some(Arc::new(Mutex::new(node)));
    let source = SourceRegistry::with_defaults().create(Backend::Local, &spec).unwrap();

    let total = 4 * demo.chunk_tokens;
    let fetcher = Fetcher::builder()
        .fetch_config(FetchConfig { chunk_tokens: demo.chunk_tokens, ..Default::default() })
        .bandwidth_gbps(8.0)
        .build();
    let req = FetchRequest::new(total, total * 6 * 8 * 32 * 2)
        .with_hashes(demo.hashes.clone())
        .resolution(ResolutionPolicy::Fixed(0))
        .exec(ExecMode::Pipelined);
    let mut session = fetcher.session(req).with_source(source);
    match session.run() {
        Err(FetchError::Transport { chunk: Some(2), detail, .. }) => {
            assert!(detail.contains("not in local store"), "{detail}")
        }
        other => panic!("wrong result {:?}", other.err()),
    }
    let report = session.report().expect("partial report kept");
    assert!(report.aborted);
    assert!(report.restored.len() <= 2);
}

// --------------------------------------------- busy admission mapping

/// A node's `Busy` admission refusal crosses the client's io boundary
/// as a typed `FetchError::Busy` carrying the server's retry hint —
/// the handshake `RemoteSource` drives its retry-with-backoff from.
#[test]
fn busy_reply_maps_to_typed_busy_error() {
    use kvfetcher::service::{FaultSpec, StoreClient};

    let demo = demo_prefix(17, 1, 32);
    let mut node = StorageNode::new(demo.chunk_tokens);
    node.register(demo.chunks[0].clone());
    let cfg = ServerConfig {
        fault: FaultSpec { busy_first_fetches: 1, ..Default::default() },
        ..Default::default()
    };
    let server = StorageServer::spawn("127.0.0.1:0", node, cfg).expect("bind");
    let client = StoreClient::connect(&server.local_addr().to_string()).expect("connect");

    // first fetch: refused with the typed Busy error + retry hint
    let err = client.fetch_chunk(demo.hashes[0], "144p").expect_err("forced busy");
    match FetchError::from_io(&err) {
        Some(FetchError::Busy { retry_after_ms }) => {
            assert!(retry_after_ms > 0, "default retry hint must be nonzero")
        }
        other => panic!("wrong typed payload {other:?} (io: {err})"),
    }
    // the fault is spent: the retry succeeds
    assert!(client.fetch_chunk(demo.hashes[0], "144p").expect("retry").is_some());
    // ...and the refusal is visible in the node's counters
    assert_eq!(client.stats().expect("stats").busy_replies, 1);
    server.shutdown();
}

/// The full TTFT primitive agrees between a `FullPrefill` profile and
/// the fetching systems (the special case the deleted shims covered).
#[test]
fn ttft_covers_full_prefill_and_fetching_profiles() {
    let dev = DeviceSpec::h20();
    let perf = PerfModel::new(dev.clone(), ModelSpec::yi_34b());
    for profile in [
        SystemProfile::kvfetcher(),
        SystemProfile::cachegen(&dev),
        SystemProfile::full_prefill(),
    ] {
        let reusable = if profile.kind == kvfetcher::baselines::SystemKind::FullPrefill {
            0
        } else {
            95_000
        };
        let facade = Fetcher::builder()
            .profile(profile.clone())
            .bandwidth(BandwidthTrace::constant(16.0))
            .for_perf(&perf)
            .build();
        let analytic = facade.ttft(&perf, 100_000, reusable, ExecMode::Analytic);
        let pipelined = facade.ttft(&perf, 100_000, reusable, ExecMode::Pipelined);
        assert!(analytic.total() > 0.0, "{}", profile.name);
        assert!(
            (analytic.total() - pipelined.total()).abs() <= 0.05 * analytic.total(),
            "{}: analytic {:.4}s vs pipelined {:.4}s",
            profile.name,
            analytic.total(),
            pipelined.total()
        );
        if profile.kind == kvfetcher::baselines::SystemKind::FullPrefill {
            assert!(analytic.transmission == 0.0 && analytic.decode == 0.0);
            assert!((analytic.prefill - perf.full_prefill_time(100_000)).abs() < 1e-12);
        }
    }
}
