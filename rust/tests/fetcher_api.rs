//! Contract tests of the unified `Fetcher` facade (ISSUE 3):
//!
//! * builder default/override matrix — the facade reproduces exactly
//!   what hand-threaded state produced;
//! * `FetchError` variant mapping from wire faults (truncated frame,
//!   oversized frame, decode mismatch) and dead shards;
//! * deprecated-shim equivalence — the old free functions and the new
//!   facade produce bit-identical results (the shims stay one release).

use std::sync::{Arc, Mutex};

use kvfetcher::asic::{h20_table, DecodePool};
use kvfetcher::baselines::SystemProfile;
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::codec::CodecConfig;
use kvfetcher::engine::ExecMode;
use kvfetcher::fetcher::transport::decode_payload;
use kvfetcher::fetcher::{
    plan_fetch, ChunkPayload, FetchConfig, FetchError, FetchRequest, Fetcher, PipelineConfig,
    ResolutionPolicy,
};
use kvfetcher::kvstore::StorageNode;
use kvfetcher::layout::{self, IntraLayout, Resolution};
use kvfetcher::net::{BandwidthEstimator, BandwidthTrace, NetLink};
use kvfetcher::quant::quantize;
use kvfetcher::service::{
    demo_prefix, protocol, Backend, Request, ServerConfig, SourceRegistry, SourceSpec,
    StorageServer, DEMO_LADDER,
};
use kvfetcher::tensor::KvCache;
use kvfetcher::util::Prng;

const RAW: usize = 100_000 * 245_760;

fn manual_plan(
    profile: &SystemProfile,
    cfg: &FetchConfig,
    gbps: f64,
    units: usize,
) -> kvfetcher::fetcher::FetchPlan {
    let mut link = NetLink::new(BandwidthTrace::constant(gbps));
    let mut pool = DecodePool::new(units, h20_table());
    let mut est = BandwidthEstimator::new(0.5);
    plan_fetch(0.0, 100_000, RAW, profile, cfg, &mut link, &mut pool, &mut est)
}

fn assert_plans_equal(a: &kvfetcher::fetcher::FetchPlan, b: &kvfetcher::fetcher::FetchPlan) {
    assert_eq!(a.chunks.len(), b.chunks.len());
    for (x, y) in a.chunks.iter().zip(&b.chunks) {
        assert_eq!(x.res_idx, y.res_idx);
        assert_eq!(x.wire_bytes, y.wire_bytes);
        assert!((x.trans_end - y.trans_end).abs() < 1e-12);
        assert!((x.dec_end - y.dec_end).abs() < 1e-12);
    }
    assert!((a.done_at - b.done_at).abs() < 1e-12);
}

// -------------------------------------------------- builder matrix

/// The builder's defaults are exactly the hand-threaded defaults every
/// call site used to repeat: kvfetcher profile, default fetch config,
/// 16 Gbps constant link, 7-unit H20 pool, 0.5-alpha estimator.
#[test]
fn builder_defaults_match_hand_threaded_state() {
    let mut f = Fetcher::builder().build();
    let report = f.run(&FetchRequest::new(100_000, RAW)).unwrap();
    let manual = manual_plan(&SystemProfile::kvfetcher(), &FetchConfig::default(), 16.0, 7);
    assert_plans_equal(&report.plan, &manual);
}

/// Every builder override lands: profile, fetch config, bandwidth,
/// decode pool, and the perf-model convenience.
#[test]
fn builder_overrides_land() {
    let dev = DeviceSpec::h20();
    // profile + bandwidth override
    let mut f = Fetcher::builder()
        .profile(SystemProfile::cachegen(&dev))
        .bandwidth_gbps(4.0)
        .build();
    let report = f.run(&FetchRequest::new(100_000, RAW)).unwrap();
    let manual = manual_plan(&SystemProfile::cachegen(&dev), &FetchConfig::default(), 4.0, 7);
    assert_plans_equal(&report.plan, &manual);

    // fetch-config override: halving chunk_tokens doubles the chunks
    let cfg = FetchConfig { chunk_tokens: 5_000, ..Default::default() };
    let mut f = Fetcher::builder().fetch_config(cfg.clone()).build();
    assert_eq!(f.run(&FetchRequest::new(100_000, RAW)).unwrap().plan.chunks.len(), 20);

    // decode-pool override via for_perf sizes like the engine
    let perf = PerfModel::new(DeviceSpec::l20(), ModelSpec::lwm_7b());
    let units = perf.dev.nvdecs * perf.n_gpus;
    let mut f = Fetcher::builder().bandwidth_gbps(16.0).for_perf(&perf).build();
    let got = f.run(&FetchRequest::new(100_000, RAW)).unwrap();
    let mut link = NetLink::new(BandwidthTrace::constant(16.0));
    let mut pool = DecodePool::new(units, perf.dev.decode_table());
    let mut est = BandwidthEstimator::new(0.5);
    let manual = plan_fetch(
        0.0,
        100_000,
        RAW,
        &SystemProfile::kvfetcher(),
        &FetchConfig::default(),
        &mut link,
        &mut pool,
        &mut est,
    );
    assert_plans_equal(&got.plan, &manual);
}

/// Request-level overrides beat the builder's config without mutating
/// it: resolution policy and queue depth are per-request.
#[test]
fn request_overrides_do_not_mutate_the_fetcher() {
    let f = Fetcher::builder().bandwidth_gbps(4.0).build();
    let mut a = f.fresh();
    let r1 = a
        .run(&FetchRequest::new(100_000, RAW).resolution(ResolutionPolicy::Fixed(1)))
        .unwrap();
    assert!(r1.plan.chunks.iter().all(|c| c.res_idx == 1));
    // the fetcher's own config is untouched: a fresh run re-adapts
    assert!(a.config().adaptive);
    let mut b = f.fresh();
    let adaptive = b.run(&FetchRequest::new(100_000, RAW)).unwrap();
    let manual = manual_plan(&SystemProfile::kvfetcher(), &FetchConfig::default(), 4.0, 7);
    assert_plans_equal(&adaptive.plan, &manual);
}

/// Consecutive runs through one fetcher contend on the shared link —
/// the facade keeps the engine's contention semantics.
#[test]
fn consecutive_runs_contend_on_shared_state() {
    let mut f = Fetcher::builder().bandwidth_gbps(8.0).build();
    let first = f.run(&FetchRequest::new(50_000, RAW / 2)).unwrap();
    let second = f.run(&FetchRequest::new(50_000, RAW / 2)).unwrap();
    assert!(
        second.plan.chunks[0].trans_start >= first.plan.chunks.last().unwrap().trans_end - 1e-9,
        "second fetch must queue behind the first on the FIFO link"
    );
    // a reset clears the carry-over
    f.reset();
    let clean = f.run(&FetchRequest::new(50_000, RAW / 2)).unwrap();
    assert_plans_equal(&clean.plan, &first.plan);
}

// ------------------------------------------- wire-fault error mapping

/// Truncated frames surface as `FetchError::Decode` with the truncation
/// named, from both the payload parser and the chunk marshaling.
#[test]
fn truncated_frame_maps_to_decode_error() {
    // a string field cut short trips the truncation check itself
    let (tag, body) = protocol::encode_request(&Request::FetchChunk {
        hash: 7,
        resolution: "1080p".into(),
    });
    match protocol::decode_request(tag, &body[..body.len() - 3]) {
        Err(FetchError::Decode { detail, .. }) => {
            assert!(detail.contains("truncated"), "{detail}")
        }
        other => panic!("wrong result {:?}", other.err()),
    }
    // a truncated chunk body trips the count bound first — still Decode
    let demo = demo_prefix(3, 1, 32);
    let (tag, body) = protocol::encode_request(&Request::PutChunk {
        chunk: demo.chunks[0].clone(),
    });
    assert!(matches!(
        protocol::decode_request(tag, &body[..body.len() - 3]),
        Err(FetchError::Decode { .. })
    ));
}

/// Oversized frames are a capacity refusal before any allocation.
#[test]
fn oversized_frame_maps_to_capacity_error() {
    match protocol::validate_frame_len(protocol::MAX_FRAME_BYTES + 1) {
        Err(FetchError::Capacity { detail }) => {
            assert!(detail.contains("MAX_FRAME_BYTES"), "{detail}")
        }
        other => panic!("wrong result {:?}", other.err()),
    }
    assert!(protocol::validate_frame_len(0).is_err());
    assert!(protocol::validate_frame_len(1024).is_ok());
}

/// Payloads whose group streams decode but disagree on the chunk shape
/// map to `FetchError::Decode` (the codec-mismatch wire fault).
#[test]
fn decode_mismatch_maps_to_decode_error() {
    let res = Resolution { name: "tiny", w: 64, h: 32 };
    let intra = IntraLayout { hr: 2, hc: 4, dr: 8, dc: 4 };
    let mut rng = Prng::new(33);
    // same plane/head geometry, different token counts
    let big = quantize(&KvCache::synthetic(&mut rng, 48, 6, 8, 32, 0.9));
    let small = quantize(&KvCache::synthetic(&mut rng, 32, 6, 8, 32, 0.9));
    let g_big = layout::encode_chunk(&big, res, intra, &CodecConfig::lossless()).unwrap();
    let g_small = layout::encode_chunk(&small, res, intra, &CodecConfig::lossless()).unwrap();
    assert!(g_big.len() >= 2 && g_small.len() >= 2);
    let frankenstein = ChunkPayload {
        hash: 1,
        tokens: big.tokens,
        resolution: "tiny".into(),
        scales: big.scales.clone(),
        group_bytes: vec![g_big[0].bytes.clone(), g_small[1].bytes.clone()],
    };
    match decode_payload(&frankenstein) {
        Err(FetchError::Decode { detail, .. }) => {
            assert!(detail.contains("disagree"), "{detail}")
        }
        other => panic!("wrong result {:?}", other.err()),
    }
    // garbage bitstreams map to Decode too (via CodecError)
    let garbage = ChunkPayload {
        hash: 0,
        tokens: 0,
        resolution: "x".into(),
        scales: vec![],
        group_bytes: vec![vec![9, 9, 9]],
    };
    assert!(matches!(decode_payload(&garbage), Err(FetchError::Decode { .. })));
}

/// A dead shard in a live fleet is attributed by index and address
/// (the satellite fix: connect failures no longer fold into a generic
/// fetch error string).
#[test]
fn dead_shard_is_attributed_by_index_and_address() {
    let demo = demo_prefix(21, 2, 32);
    let server = StorageServer::spawn(
        "127.0.0.1:0",
        StorageNode::new(demo.chunk_tokens),
        ServerConfig::default(),
    )
    .expect("bind");
    let live = server.local_addr().to_string();
    let mut spec = SourceSpec::new(demo.hashes.clone(), DEMO_LADDER);
    spec.addrs = vec![live, "127.0.0.1:1".into()]; // shard 1 is dead
    match SourceRegistry::with_defaults().create(Backend::Tcp, &spec) {
        Err(FetchError::Connect { shard, addr, .. }) => {
            assert_eq!(shard, 1);
            assert_eq!(addr, "127.0.0.1:1");
        }
        other => panic!("wrong result {:?}", other.err()),
    }
    server.shutdown();
}

/// A sourced fetch that hits a missing chunk surfaces a typed transport
/// error naming the chunk, and the session keeps the partial report.
#[test]
fn missing_chunk_fails_the_session_with_a_transport_error() {
    let demo = demo_prefix(7, 4, 32);
    // register only the first two chunks
    let mut node = StorageNode::new(demo.chunk_tokens);
    for c in demo.chunks.iter().take(2) {
        node.register(c.clone());
    }
    let mut spec = SourceSpec::new(demo.hashes.clone(), DEMO_LADDER);
    spec.node = Some(Arc::new(Mutex::new(node)));
    let source = SourceRegistry::with_defaults().create(Backend::Local, &spec).unwrap();

    let total = 4 * demo.chunk_tokens;
    let fetcher = Fetcher::builder()
        .fetch_config(FetchConfig { chunk_tokens: demo.chunk_tokens, ..Default::default() })
        .bandwidth_gbps(8.0)
        .build();
    let req = FetchRequest::new(total, total * 6 * 8 * 32 * 2)
        .with_hashes(demo.hashes.clone())
        .resolution(ResolutionPolicy::Fixed(0))
        .exec(ExecMode::Pipelined);
    let mut session = fetcher.session(req).with_source(source);
    match session.run() {
        Err(FetchError::Transport { chunk: Some(2), detail, .. }) => {
            assert!(detail.contains("not in local store"), "{detail}")
        }
        other => panic!("wrong result {:?}", other.err()),
    }
    let report = session.report().expect("partial report kept");
    assert!(report.aborted);
    assert!(report.restored.len() <= 2);
}

// ------------------------------------------- deprecated-shim equivalence

/// The `#[deprecated]` free functions are thin shims over the facade:
/// old fn == new facade, bit-exact (plans, link state, restored bytes).
#[test]
#[allow(deprecated)]
fn deprecated_shims_are_bit_exact_with_the_facade() {
    use kvfetcher::fetcher::{
        execute_fetch, execute_fetch_with_source, spawn_fetch, CancelToken, FetchParams,
    };
    use kvfetcher::service::LocalSource;

    let profile = SystemProfile::kvfetcher();
    let params = FetchParams {
        now: 0.0,
        reusable_tokens: 100_000,
        raw_bytes_total: RAW,
        profile: profile.clone(),
        cfg: FetchConfig::default(),
    };

    // execute_fetch == facade pipelined run
    let mut link = NetLink::new(BandwidthTrace::constant(8.0));
    let mut pool = DecodePool::new(7, h20_table());
    let mut est = BandwidthEstimator::new(0.5);
    let old = execute_fetch(
        &params,
        &PipelineConfig::default(),
        &CancelToken::new(),
        &mut link,
        &mut pool,
        &mut est,
    );
    let mut f = Fetcher::builder().profile(profile.clone()).bandwidth_gbps(8.0).build();
    let new = f.run(&FetchRequest::new(100_000, RAW).exec(ExecMode::Pipelined)).unwrap();
    assert_plans_equal(&old.plan, &new.plan);
    assert_eq!(old.chunks_completed, new.chunks_completed);
    assert!((link.busy_until() - f.link().busy_until()).abs() < 1e-12);
    assert_eq!(link.bytes_sent, f.link().bytes_sent);

    // spawn_fetch == session spawn
    let job = spawn_fetch(
        params.clone(),
        PipelineConfig::default(),
        NetLink::new(BandwidthTrace::constant(8.0)),
        DecodePool::new(7, h20_table()),
        BandwidthEstimator::new(0.5),
    );
    let (old_out, old_link, _, _) = job.join();
    let new_job = f
        .fresh()
        .session(FetchRequest::new(100_000, RAW).exec(ExecMode::Pipelined))
        .spawn();
    let (mut session, result) = new_job.join();
    result.unwrap();
    let new_out = session.take_report().unwrap();
    assert_plans_equal(&old_out.plan, &new_out.plan);
    assert_eq!(old_link.bytes_sent, session.into_fetcher().link().bytes_sent);

    // execute_fetch_with_source == session with_source (restored bytes)
    let demo = demo_prefix(3, 4, 32);
    let node = {
        let mut n = StorageNode::new(demo.chunk_tokens);
        for c in &demo.chunks {
            n.register(c.clone());
        }
        Arc::new(Mutex::new(n))
    };
    let total = 4 * demo.chunk_tokens;
    let demo_params = FetchParams {
        now: 0.0,
        reusable_tokens: total,
        raw_bytes_total: total * 6 * 8 * 32 * 2,
        profile: profile.clone(),
        cfg: FetchConfig {
            chunk_tokens: demo.chunk_tokens,
            adaptive: false,
            fixed_res: 0,
            ..Default::default()
        },
    };
    let mut src_old = LocalSource::new(Arc::clone(&node), demo.hashes.clone(), DEMO_LADDER);
    let mut link = NetLink::new(BandwidthTrace::constant(8.0));
    let mut pool = DecodePool::new(7, h20_table());
    let mut est = BandwidthEstimator::new(0.5);
    let old = execute_fetch_with_source(
        &demo_params,
        &PipelineConfig::default(),
        &CancelToken::new(),
        &mut link,
        &mut pool,
        &mut est,
        Some(&mut src_old),
    );
    let src_new = Box::new(LocalSource::new(node, demo.hashes.clone(), DEMO_LADDER));
    let fetcher = Fetcher::builder()
        .profile(profile)
        .fetch_config(demo_params.cfg.clone())
        .bandwidth_gbps(8.0)
        .build();
    let mut session = fetcher
        .session(
            FetchRequest::new(total, demo_params.raw_bytes_total)
                .with_hashes(demo.hashes.clone())
                .exec(ExecMode::Pipelined),
        )
        .with_source(src_new);
    session.run().unwrap();
    let new = session.take_report().unwrap();
    assert_plans_equal(&old.plan, &new.plan);
    assert_eq!(old.restored.len(), new.restored.len());
    for (a, b) in old.restored.iter().zip(&new.restored) {
        assert_eq!(a.idx, b.idx);
        assert_eq!(a.quant.data, b.quant.data, "restored bytes must be bit-exact");
        assert_eq!(a.quant.scales, b.quant.scales);
    }
}

/// The deprecated TTFT primitives equal `Fetcher::ttft` across modes
/// and profiles (including the FullPrefill special case).
#[test]
#[allow(deprecated)]
fn deprecated_ttft_shims_equal_facade_ttft() {
    use kvfetcher::engine::{single_request_ttft, single_request_ttft_exec};

    let dev = DeviceSpec::h20();
    let perf = PerfModel::new(dev.clone(), ModelSpec::yi_34b());
    let bw = BandwidthTrace::constant(16.0);
    let cfg = FetchConfig::default();
    for profile in [
        SystemProfile::kvfetcher(),
        SystemProfile::cachegen(&dev),
        SystemProfile::full_prefill(),
    ] {
        let reusable = if profile.kind == kvfetcher::baselines::SystemKind::FullPrefill {
            0
        } else {
            95_000
        };
        let facade = Fetcher::builder()
            .profile(profile.clone())
            .fetch_config(cfg.clone())
            .bandwidth(bw.clone())
            .for_perf(&perf)
            .build();
        for exec in [ExecMode::Analytic, ExecMode::Pipelined] {
            let old =
                single_request_ttft_exec(&perf, &profile, &cfg, &bw, 100_000, reusable, exec);
            let new = facade.ttft(&perf, 100_000, reusable, exec);
            assert!((old.total() - new.total()).abs() < 1e-12, "{} {exec:?}", profile.name);
            assert!((old.prefill - new.prefill).abs() < 1e-12);
            assert!((old.transmission - new.transmission).abs() < 1e-12);
        }
        let old = single_request_ttft(&perf, &profile, &cfg, &bw, 100_000, reusable);
        let new = facade.ttft(&perf, 100_000, reusable, ExecMode::Analytic);
        assert!((old.total() - new.total()).abs() < 1e-12, "{}", profile.name);
    }
}
