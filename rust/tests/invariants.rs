//! Additional cross-cutting invariants and edge cases, complementing the
//! per-module unit tests.

use kvfetcher::asic::{encode_pool, h20_table, l20_table, DecodePool};
use kvfetcher::baselines::SystemProfile;
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::engine::{EngineConfig, EngineSim, ExecMode};
use kvfetcher::fetcher::{restore_memory, select_resolution, FetchConfig, RES_SIZE_FACTOR};
use kvfetcher::layout::{resolution_by_name, RESOLUTIONS};
use kvfetcher::metrics::Recorder;
use kvfetcher::net::{BandwidthEstimator, BandwidthTrace};
use kvfetcher::quant::quantize;
use kvfetcher::tensor::KvCache;
use kvfetcher::trace::{generate, TraceConfig};
use kvfetcher::util::{proptest, Prng};

// ------------------------------------------------------------------ layout
#[test]
fn resolution_ladder_is_8_aligned_and_named() {
    for r in RESOLUTIONS {
        assert_eq!(r.w % 8, 0, "{}", r.name);
        assert_eq!(r.h % 8, 0, "{}", r.name);
        assert_eq!(resolution_by_name(r.name).unwrap(), r);
    }
    assert!(resolution_by_name("4k").is_none());
    // ladder is strictly increasing in area
    for w in RESOLUTIONS.windows(2) {
        assert!(w[1].w * w[1].h > w[0].w * w[0].h);
    }
}

// --------------------------------------------------------------------- net
#[test]
fn prop_transfer_time_consistent_with_trace_integral() {
    // transferring A then B back-to-back equals transferring A+B
    proptest::check(71, 30, "transfer-additivity", |rng| {
        let tr = BandwidthTrace::jitter(rng.next_u64(), 8.0, 1.0, 30.0, 0.7, 2000.0);
        let t0 = rng.f64_range(0.0, 50.0);
        let a = 1 + rng.below(200_000_000) as usize;
        let b = 1 + rng.below(200_000_000) as usize;
        let ta = tr.transfer_time(a, t0);
        let tb = tr.transfer_time(b, t0 + ta);
        let tab = tr.transfer_time(a + b, t0);
        if (ta + tb - tab).abs() > 1e-6 * tab.max(1.0) {
            return Err(format!("additivity violated: {ta}+{tb} != {tab}"));
        }
        Ok(())
    });
}

#[test]
fn estimator_ignores_degenerate_observations() {
    let mut est = BandwidthEstimator::new(0.3);
    est.observe(1_000_000, 0.0); // zero-duration: must be ignored
    assert_eq!(est.estimate(5.0), 5.0);
    est.observe(125_000_000, 1.0); // 1 Gbps
    assert!((est.estimate(5.0) - 1.0).abs() < 1e-9);
}

// -------------------------------------------------------------------- asic
#[test]
fn encode_pool_is_slower_than_decode_pool() {
    let mut dec = DecodePool::new(2, h20_table());
    let mut enc = encode_pool(2, h20_table());
    let d = dec.decode(0.0, 3, 1.0);
    let e = enc.decode(0.0, 3, 1.0);
    assert!((e.end - e.start) > (d.end - d.start) * 1.5, "NVENC ~2x NVDEC latency");
}

#[test]
fn pool_units_chosen_round_robin_by_availability() {
    let mut pool = DecodePool::new(3, l20_table());
    let j1 = pool.decode(0.0, 3, 1.0);
    let j2 = pool.decode(0.0, 3, 1.0);
    let j3 = pool.decode(0.0, 3, 1.0);
    let units: std::collections::BTreeSet<_> = [j1.unit, j2.unit, j3.unit].into();
    assert_eq!(units.len(), 3, "three concurrent jobs must use three units");
}

// ------------------------------------------------------------------ fetcher
#[test]
fn res_size_factors_match_paper_table_ratios() {
    assert!((RES_SIZE_FACTOR[0] - 180.0 / 256.0).abs() < 1e-12);
    assert_eq!(RES_SIZE_FACTOR[3], 1.0);
    for w in RES_SIZE_FACTOR.windows(2) {
        assert!(w[1] > w[0], "sizes grow with resolution");
    }
}

#[test]
fn resolution_choice_monotone_in_bandwidth() {
    // more bandwidth must never select a *smaller* resolution
    let pool = DecodePool::new(7, h20_table());
    let mut last = 0usize;
    for bw in [1.0, 2.0, 4.0, 6.0, 10.0, 20.0, 50.0] {
        let r = select_resolution(bw, 256_000_000, &pool, 0.0, 1.0);
        assert!(r >= last, "bw {bw}: res {r} < previous {last}");
        last = r;
    }
    assert_eq!(last, 3, "high bandwidth ends at 1080p");
}

#[test]
fn smartnic_restore_is_off_device() {
    let cfg = FetchConfig::default();
    assert_eq!(restore_memory(&SystemProfile::shadowserve(), &cfg, 1 << 30), 0);
    assert_eq!(restore_memory(&SystemProfile::raw_reuse(), &cfg, 1 << 30), 0);
}

// ------------------------------------------------------------------ engine
#[test]
fn full_prefill_engine_never_fetches() {
    let perf = PerfModel::new(DeviceSpec::h20(), ModelSpec::yi_34b());
    let trace = generate(&TraceConfig {
        seed: 4,
        n_requests: 8,
        reuse_frac: 1.0,
        ctx_min: 50_000,
        ctx_max: 100_000,
        ..Default::default()
    });
    let mut eng = EngineSim::new(
        perf,
        SystemProfile::full_prefill(),
        EngineConfig { layerwise_pipeline: false, ..Default::default() },
        BandwidthTrace::constant(16.0),
    );
    let rec = eng.run(&trace);
    assert!(rec.records.iter().all(|r| r.reused_tokens == 0));
    assert_eq!(eng.fetcher.link().bytes_sent, 0, "full prefill must move zero bytes");
    assert_eq!(eng.fetcher.pool().jobs_done, 0);
}

#[test]
fn records_are_causally_ordered() {
    let perf = PerfModel::new(DeviceSpec::a100(), ModelSpec::lwm_7b());
    let trace = generate(&TraceConfig { seed: 10, n_requests: 16, ..Default::default() });
    let mut eng = EngineSim::new(
        perf,
        SystemProfile::kvfetcher(),
        EngineConfig::default(),
        BandwidthTrace::constant(16.0),
    );
    for r in &eng.run(&trace).records {
        assert!(r.first_token_at > r.arrival, "req {}", r.id);
        assert!(r.finished_at >= r.first_token_at, "req {}", r.id);
    }
}

#[test]
fn zero_reusable_context_takes_full_prefill_path() {
    // a request below the reuse threshold must cost the same under
    // KVFetcher as under FullPrefill when served alone
    let perf = PerfModel::new(DeviceSpec::h20(), ModelSpec::yi_34b());
    let a = kvfetcher::fetcher::Fetcher::builder()
        .profile(SystemProfile::full_prefill())
        .bandwidth(BandwidthTrace::constant(16.0))
        .for_perf(&perf)
        .build()
        .ttft(&perf, 30_000, 0, ExecMode::Analytic);
    assert!(a.transmission == 0.0 && a.decode == 0.0);
    assert!(a.prefill > 0.0);
}

// ----------------------------------------------------------------- metrics
#[test]
fn recorder_empty_summaries_are_safe() {
    let rec = Recorder::default();
    let s = rec.ttft_summary(None);
    assert_eq!(s.n, 0);
    assert_eq!(s.mean, 0.0);
    assert_eq!(rec.p90_ttft(), 0.0);
}

// ------------------------------------------------------------------- quant
#[test]
fn quantize_handles_extreme_values() {
    let mut kv = KvCache::zeros(4, 2, 2, 2);
    kv.data[0] = 1e30;
    kv.data[1] = -1e30;
    kv.data[2] = f32::MIN_POSITIVE;
    let q = quantize(&kv);
    assert!(q.data.iter().all(|&b| b <= 255));
    assert!(q.scales.iter().all(|s| s.is_finite() && *s > 0.0));
}

// ------------------------------------------------------------------- trace
#[test]
fn prop_trace_generation_total_function() {
    proptest::check(73, 25, "trace-total", |rng: &mut Prng| {
        let cfg = TraceConfig {
            seed: rng.next_u64(),
            n_requests: 1 + rng.below(50) as usize,
            rate: rng.f64_range(0.01, 5.0),
            ctx_min: 100 + rng.below(1000) as usize,
            ctx_max: 2_000 + rng.below(100_000) as usize,
            reuse_frac: rng.f64(),
            reuse_share: rng.f64_range(0.5, 1.0),
            reuse_threshold: rng.below(50_000) as usize,
            out_min: 1,
            out_max: 2 + rng.below(100) as usize,
        };
        let tr = generate(&cfg);
        if tr.len() != cfg.n_requests {
            return Err("wrong count".into());
        }
        for r in &tr {
            if r.reusable_tokens > r.context_tokens {
                return Err(format!("reusable > ctx for req {}", r.id));
            }
            if r.is_fetch() && r.suffix_tokens() == 0 {
                return Err("fetch request with empty suffix".into());
            }
        }
        Ok(())
    });
}
