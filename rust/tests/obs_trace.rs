//! End-to-end observability contracts (ISSUE 7): the trace recorder
//! rides the real TCP fetch path and its export is a faithful,
//! Perfetto-loadable account of the run.
//!
//! Acceptance:
//! * the exported Chrome trace-event JSON parses and is schema-shaped
//!   (process/thread metadata, `ph:"X"` slices with `dur`, `ph:"i"`
//!   thread-scoped instants);
//! * per chunk, the wall-clock spans are properly nested: transmit ends
//!   before decode starts, decode ends before restore starts, and each
//!   track's spans are time-ordered;
//! * every restored chunk has exactly one transmit/decode/restore span
//!   triple — 100% coverage, no extras;
//! * the transmit span's `shard` arg matches the serving replica the
//!   source reported in `WireTiming.shard`;
//! * with no recorder attached the fetch restores bit-identically on
//!   an unchanged virtual timeline — tracing off costs nothing;
//! * (ISSUE 8) the CAS path lands `manifest_resolve` / `object_get`
//!   spans and `cache_hit` / `cache_miss` instants on its own track,
//!   and they survive into the Perfetto export.

use std::sync::Arc;

use kvfetcher::asic::{h20_table, DecodePool};
use kvfetcher::baselines::SystemProfile;
use kvfetcher::engine::ExecMode;
use kvfetcher::fetcher::{FetchConfig, FetchReport, FetchRequest, Fetcher, ResolutionPolicy};
use kvfetcher::kvstore::StorageNode;
use kvfetcher::net::BandwidthTrace;
use kvfetcher::obs::{ArgValue, ObsConfig, TraceEvent, TraceRecorder, Track};
use kvfetcher::service::{
    demo_prefix, Backend, DemoPrefix, Placement, ServerConfig, ShardRouter, SourceRegistry,
    SourceSpec, StorageServer, DEMO_HEADS, DEMO_HEAD_DIM, DEMO_LADDER, DEMO_PLANES,
};
use kvfetcher::util::json::Json;

fn demo_request(demo: &DemoPrefix) -> FetchRequest {
    let total_tokens = demo.hashes.len() * demo.chunk_tokens;
    FetchRequest::new(total_tokens, total_tokens * DEMO_PLANES * DEMO_HEADS * DEMO_HEAD_DIM * 2)
        .with_hashes(demo.hashes.clone())
        .resolution(ResolutionPolicy::Fixed(3))
        .exec(ExecMode::Pipelined)
}

/// Spawn `n` loopback shards and register the demo chunks round-robin.
fn spawn_shards(demo: &DemoPrefix, n: usize) -> (Vec<StorageServer>, Vec<String>) {
    let mut servers = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..n {
        let node = StorageNode::new(demo.chunk_tokens);
        let server =
            StorageServer::spawn("127.0.0.1:0", node, ServerConfig::default()).expect("bind");
        addrs.push(server.local_addr().to_string());
        servers.push(server);
    }
    let router = ShardRouter::connect(&addrs, Placement::RoundRobin).expect("connect");
    for (i, chunk) in demo.chunks.iter().enumerate() {
        let out = router.put_chunk(i, chunk);
        assert!(out.all_stored(), "chunk {i} must register: {out:?}");
    }
    (servers, addrs)
}

/// One pipelined demo fetch over TCP, with the recorder (when given)
/// shared between the executor and the remote source.
fn tcp_fetch(
    demo: &DemoPrefix,
    addrs: &[String],
    rec: Option<Arc<TraceRecorder>>,
) -> FetchReport {
    let mut spec = SourceSpec::new(demo.hashes.clone(), DEMO_LADDER);
    spec.addrs = addrs.to_vec();
    spec.tokens = demo.tokens.clone();
    spec.chunk_tokens = demo.chunk_tokens;
    spec.recorder = rec.clone();
    let source = SourceRegistry::with_defaults().create(Backend::Tcp, &spec).expect("tcp source");
    let fetcher = Fetcher::builder()
        .profile(SystemProfile::kvfetcher())
        .fetch_config(FetchConfig { chunk_tokens: demo.chunk_tokens, ..Default::default() })
        .bandwidth(BandwidthTrace::constant(8.0))
        .decode_pool(DecodePool::new(7, h20_table()))
        .recorder(rec)
        .build();
    let mut session = fetcher.session(demo_request(demo)).with_source(source);
    session.run().expect("demo fetch");
    session.take_report().expect("report stored")
}

fn u64_arg(e: &TraceEvent, key: &str) -> Option<u64> {
    e.args.iter().find(|(k, _)| *k == key).and_then(|(_, v)| match v {
        ArgValue::U64(x) => Some(*x),
        _ => None,
    })
}

/// The per-chunk span of `name` on `track` — asserting there is exactly
/// one (the coverage contract: one triple per restored chunk).
fn span_of<'e>(events: &'e [TraceEvent], track: Track, name: &str, chunk: u64) -> &'e TraceEvent {
    let matches: Vec<&TraceEvent> = events
        .iter()
        .filter(|e| e.track == track && e.name == name && u64_arg(e, "chunk") == Some(chunk))
        .collect();
    assert_eq!(matches.len(), 1, "chunk {chunk} needs exactly one {name} span");
    let e = matches[0];
    assert!(e.dur_us.is_some(), "{name} must be a complete span, not an instant");
    e
}

/// Exported Chrome JSON parses back and is schema-shaped: metadata
/// names the process and every declared track, slices carry `dur`,
/// instants carry `s:"t"`, and every event sits on a declared track.
#[test]
fn chrome_export_parses_and_is_schema_shaped() {
    let demo = demo_prefix(21, 4, 32);
    let (servers, addrs) = spawn_shards(&demo, 2);
    let rec = TraceRecorder::new(1 << 16);
    let report = tcp_fetch(&demo, &addrs, Some(rec.clone()));
    assert_eq!(report.restored.len(), 4);
    assert_eq!(rec.dropped(), 0, "a 64k ring must hold a 4-chunk run");

    let doc = rec.to_chrome_json();
    let parsed = Json::parse(&doc.to_string()).expect("export must parse back");
    assert_eq!(parsed.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    assert_eq!(parsed.get("droppedEvents").and_then(Json::as_usize), Some(0));
    let events = parsed.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");

    let metas: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
        .collect();
    assert_eq!(metas.len(), 1 + Track::all().len(), "process + one name per track");
    let thread_names: Vec<&str> = metas
        .iter()
        .filter(|m| m.get("name").and_then(Json::as_str) == Some("thread_name"))
        .filter_map(|m| m.get("args").and_then(|a| a.get("name")).and_then(Json::as_str))
        .collect();
    for t in Track::all() {
        assert!(thread_names.contains(&t.label()), "missing thread_name for {}", t.label());
    }

    let tids: Vec<usize> = Track::all().iter().map(|t| t.tid() as usize).collect();
    let mut slices = 0;
    for e in events.iter().filter(|e| e.get("ph").and_then(Json::as_str) != Some("M")) {
        let ph = e.get("ph").and_then(Json::as_str).expect("ph");
        assert!(e.get("name").and_then(Json::as_str).is_some());
        assert!(e.get("ts").and_then(Json::as_f64).is_some());
        let tid = e.get("tid").and_then(Json::as_usize).expect("tid");
        assert!(tids.contains(&tid), "event on undeclared track {tid}");
        match ph {
            "X" => {
                slices += 1;
                assert!(e.get("dur").and_then(Json::as_f64).is_some(), "slice needs dur");
            }
            "i" => assert_eq!(e.get("s").and_then(Json::as_str), Some("t")),
            other => panic!("unexpected phase {other:?}"),
        }
    }
    // at minimum the 3 executor spans per chunk made it out
    assert!(slices >= 3 * 4, "expected >= 12 slices, got {slices}");

    for s in servers {
        s.shutdown();
    }
}

/// Per-chunk coverage and ordering: every restored chunk has exactly
/// one transmit/decode/restore triple, the triple nests in wall-clock
/// order, each track's spans are time-sorted, and the transmit span's
/// `shard` arg agrees with `WireTiming.shard`.
#[test]
fn span_triples_cover_chunks_nested_with_shard_attribution() {
    let n_chunks = 6;
    let demo = demo_prefix(22, n_chunks, 32);
    let (servers, addrs) = spawn_shards(&demo, 2);
    let rec = TraceRecorder::new(1 << 16);
    let report = tcp_fetch(&demo, &addrs, Some(rec.clone()));
    assert_eq!(report.restored.len(), n_chunks);
    let events = rec.events();

    for d in &report.restored {
        let chunk = d.idx as u64;
        let t = span_of(&events, Track::Transmit, "transmit", chunk);
        let dec = span_of(&events, Track::Decode, "decode", chunk);
        let r = span_of(&events, Track::Restore, "restore", chunk);
        // hand-off order: a stage's span closes before the next opens
        assert!(
            dec.ts_us >= t.ts_us + t.dur_us.unwrap(),
            "chunk {chunk}: decode starts inside transmit"
        );
        assert!(
            r.ts_us >= dec.ts_us + dec.dur_us.unwrap(),
            "chunk {chunk}: restore starts inside decode"
        );
        // attribution: the span names the replica the source used
        let timing = report
            .wire_timings
            .iter()
            .find(|w| w.idx == d.idx)
            .expect("tcp source reports one wire timing per chunk");
        assert_eq!(
            u64_arg(t, "shard"),
            timing.shard.map(|s| s as u64),
            "chunk {chunk}: transmit shard arg vs WireTiming.shard"
        );
        // the span carries the virtual wire estimate the planner used
        assert!(u64_arg(t, "wire_bytes").is_some_and(|b| b > 0));
        assert_eq!(u64_arg(r, "restored_bytes"), Some(d.quant.data.len() as u64));
    }
    // exactly one triple per chunk and nothing else on those tracks
    for (track, name) in
        [(Track::Transmit, "transmit"), (Track::Decode, "decode"), (Track::Restore, "restore")]
    {
        let spans: Vec<&TraceEvent> = events.iter().filter(|e| e.track == track).collect();
        assert_eq!(spans.len(), n_chunks, "{name}: one span per chunk, no extras");
        assert!(
            spans.windows(2).all(|w| w[0].ts_us <= w[1].ts_us),
            "{name} spans must be time-ordered"
        );
    }

    for s in servers {
        s.shutdown();
    }
}

/// CAS-path observability: across a cold and a warm pass sharing one
/// edge cache, every chunk gets exactly one `manifest_resolve` +
/// `object_get` span per pass on the cas track, the cold pass records
/// one `cache_miss` per chunk and the warm pass one `cache_hit`, and
/// the export carries the cas track and all four event names.
#[test]
fn cas_spans_and_cache_instants_cover_both_passes() {
    use kvfetcher::cas::{publish_prefix, CasSource, DirStore, EdgeCache, Manifest};

    let n_chunks = 4;
    let demo = demo_prefix(31, n_chunks, 32);
    let dir = std::env::temp_dir().join(format!("kvfetcher-obs-cas-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = DirStore::open(&dir).expect("open store");
    let mut node = StorageNode::new(demo.chunk_tokens);
    for c in &demo.chunks {
        node.register(c.clone());
    }
    publish_prefix(&store, &node, &demo.hashes, &["144p", "240p"]).expect("publish");

    let rec = TraceRecorder::new(1 << 16);
    let cache = Arc::new(EdgeCache::new(64 << 20));
    for _pass in 0..2 {
        let store = DirStore::open(&dir).expect("open store");
        let key = Manifest::key_for(&demo.hashes);
        let manifest =
            Manifest::decode(&store.get_manifest(&key).expect("IO").expect("published"))
                .expect("manifest decodes");
        let source =
            CasSource::new(store, manifest, demo.hashes.clone(), DEMO_LADDER, cache.clone())
                .expect("chain matches")
                .with_recorder(Some(rec.clone()));
        let fetcher = Fetcher::builder()
            .profile(SystemProfile::kvfetcher())
            .fetch_config(FetchConfig { chunk_tokens: demo.chunk_tokens, ..Default::default() })
            .bandwidth(BandwidthTrace::constant(8.0))
            .decode_pool(DecodePool::new(7, h20_table()))
            .recorder(Some(rec.clone()))
            .build();
        let mut session = fetcher.session(demo_request(&demo)).with_source(Box::new(source));
        session.run().expect("cas fetch");
    }

    let events = rec.events();
    let cas: Vec<&TraceEvent> = events.iter().filter(|e| e.track == Track::Cas).collect();
    for chunk in 0..n_chunks as u64 {
        for name in ["manifest_resolve", "object_get"] {
            let spans: Vec<_> = cas
                .iter()
                .filter(|e| e.name == name && u64_arg(e, "chunk") == Some(chunk))
                .collect();
            assert_eq!(spans.len(), 2, "chunk {chunk}: one {name} span per pass");
            assert!(spans.iter().all(|e| e.dur_us.is_some()), "{name} must be a span");
        }
    }
    let count = |name: &str| cas.iter().filter(|e| e.name == name).count();
    assert_eq!(count("cache_miss"), n_chunks, "the cold pass misses once per chunk");
    assert_eq!(count("cache_hit"), n_chunks, "the warm pass hits once per chunk");
    assert_eq!(count("cache_evict"), 0, "a 64 MiB cache never evicts the demo");

    let doc = rec.to_chrome_json().to_string();
    for needle in ["\"cas\"", "manifest_resolve", "object_get", "cache_hit", "cache_miss"] {
        assert!(doc.contains(needle), "export must mention {needle}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Tracing off is absent, not muted: a run with no recorder restores
/// bit-identically to the traced run on an unchanged virtual timeline,
/// and a default (disabled) config builds no recorder at all.
#[test]
fn disabled_recorder_leaves_the_fetch_path_untouched() {
    assert!(ObsConfig::default().recorder().is_none(), "tracing defaults to off");

    let n_chunks = 4;
    let demo = demo_prefix(23, n_chunks, 32);
    let (servers, addrs) = spawn_shards(&demo, 2);
    let rec = TraceRecorder::new(1 << 16);
    let traced = tcp_fetch(&demo, &addrs, Some(rec.clone()));
    let plain = tcp_fetch(&demo, &addrs, None);

    for (a, b) in traced.restored.iter().zip(&plain.restored) {
        assert_eq!(a.idx, b.idx);
        assert_eq!(a.quant.data, b.quant.data, "restores must be bit-identical");
        assert_eq!(a.quant.scales, b.quant.scales);
    }
    for (d, q) in plain.restored.iter().zip(&demo.quants) {
        assert_eq!(d.quant.data, q.data, "untraced restore vs ground truth");
    }
    // the virtual timeline is deterministic and tracing never moves it
    assert_eq!(traced.plan.chunks.len(), plain.plan.chunks.len());
    for (a, b) in traced.plan.chunks.iter().zip(&plain.plan.chunks) {
        assert_eq!(a.res_idx, b.res_idx);
        assert_eq!(a.wire_bytes, b.wire_bytes);
        assert!((a.trans_end - b.trans_end).abs() < 1e-9);
        assert!((a.dec_end - b.dec_end).abs() < 1e-9);
    }
    assert!((traced.done_at() - plain.done_at()).abs() < 1e-9);
    // the traced run recorded real work; the plain run had nowhere to
    assert!(!rec.is_empty());
    assert!(traced.stage_summary().contains("transmit"), "CLI summary covers the stages");

    for s in servers {
        s.shutdown();
    }
}
