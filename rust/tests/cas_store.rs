//! Content-addressed store contracts (ISSUE 8): publish the demo
//! prefix into a `DirStore`, fetch it back through the `cas` backend,
//! and hold the CDN-path promises the CLI and CI rely on.
//!
//! Acceptance:
//! * a `cas` fetch restores bit-identically to the `local` backend and
//!   to ground truth — the store round-trips encoded payloads exactly;
//! * two prefixes sharing a system-prompt head store the shared chunks'
//!   objects exactly once (cross-prefix dedup ratio > 1) and both still
//!   restore bit-exactly;
//! * a second fetch through the same edge cache is served from memory
//!   (hits == objects, no new store GETs);
//! * truncated or corrupted manifests and digest-mismatched objects
//!   fail with typed `CodecError` / `FetchError` values — never a
//!   panic, never a silently wrong restore.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use kvfetcher::asic::{h20_table, DecodePool};
use kvfetcher::baselines::SystemProfile;
use kvfetcher::cas::{
    publish_prefix, store_dedup, CasSource, DirStore, EdgeCache, Manifest, PublishReport,
};
use kvfetcher::codec::CodecError;
use kvfetcher::engine::ExecMode;
use kvfetcher::fetcher::{
    FetchConfig, FetchError, FetchReport, FetchRequest, Fetcher, ResolutionPolicy, TransportSource,
};
use kvfetcher::kvstore::StorageNode;
use kvfetcher::net::BandwidthTrace;
use kvfetcher::service::{
    demo_prefix, Backend, DemoPrefix, SourceRegistry, SourceSpec, DEMO_HEADS, DEMO_HEAD_DIM,
    DEMO_LADDER, DEMO_PLANES,
};

/// Fresh per-test scratch directory (no tempfile dep in a std-only
/// crate); recreated empty so reruns never see stale objects.
fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("kvfetcher-cas-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    dir
}

/// Publish the demo prefix `(seed, n_chunks, 32)` at both demo
/// resolutions and return it with the publish accounting.
fn publish_demo(store: &DirStore, seed: u64, n_chunks: usize) -> (DemoPrefix, PublishReport) {
    let demo = demo_prefix(seed, n_chunks, 32);
    let mut node = StorageNode::new(demo.chunk_tokens);
    for c in &demo.chunks {
        node.register(c.clone());
    }
    let report =
        publish_prefix(store, &node, &demo.hashes, &["144p", "240p"]).expect("publish demo");
    (demo, report)
}

fn demo_request(demo: &DemoPrefix) -> FetchRequest {
    let total_tokens = demo.hashes.len() * demo.chunk_tokens;
    FetchRequest::new(total_tokens, total_tokens * DEMO_PLANES * DEMO_HEADS * DEMO_HEAD_DIM * 2)
        .with_hashes(demo.hashes.clone())
        .resolution(ResolutionPolicy::Fixed(3))
        .exec(ExecMode::Pipelined)
}

/// One pipelined demo fetch through the given source.
fn fetch_via(
    demo: &DemoPrefix,
    source: Box<dyn TransportSource>,
) -> Result<FetchReport, FetchError> {
    let fetcher = Fetcher::builder()
        .profile(SystemProfile::kvfetcher())
        .fetch_config(FetchConfig { chunk_tokens: demo.chunk_tokens, ..Default::default() })
        .bandwidth(BandwidthTrace::constant(8.0))
        .decode_pool(DecodePool::new(7, h20_table()))
        .build();
    let mut session = fetcher.session(demo_request(demo)).with_source(source);
    session.run()?;
    Ok(session.take_report().expect("run stores a report"))
}

/// Open a CAS source on the published store for the demo's chain.
fn cas_source(dir: &Path, demo: &DemoPrefix, cache: Arc<EdgeCache>) -> CasSource {
    let store = DirStore::open(dir).expect("open store");
    let key = Manifest::key_for(&demo.hashes);
    let bytes = store.get_manifest(&key).expect("manifest IO").expect("manifest published");
    let manifest = Manifest::decode(&bytes).expect("manifest decodes");
    CasSource::new(store, manifest, demo.hashes.clone(), DEMO_LADDER, cache).expect("chain matches")
}

#[test]
fn cas_fetch_is_bit_identical_to_local_backend() {
    let dir = tmpdir("roundtrip");
    let (demo, pub_report) = publish_demo(&DirStore::open(&dir).expect("open"), 42, 4);
    // 4 chunks x 2 resolutions, nothing published before: all new
    assert_eq!(pub_report.chunks, 4);
    assert_eq!(pub_report.objects_new, 8);
    assert_eq!(pub_report.objects_shared, 0);

    let cache = Arc::new(EdgeCache::new(64 << 20));
    let cas = fetch_via(&demo, Box::new(cas_source(&dir, &demo, cache))).expect("cas fetch");
    assert_eq!(cas.backend, Some("cas"));
    assert_eq!(cas.restored.len(), 4);

    let mut spec = SourceSpec::new(demo.hashes.clone(), DEMO_LADDER);
    spec.chunk_tokens = demo.chunk_tokens;
    let mut node = StorageNode::new(demo.chunk_tokens);
    for c in &demo.chunks {
        node.register(c.clone());
    }
    spec.node = Some(Arc::new(std::sync::Mutex::new(node)));
    let local = SourceRegistry::with_defaults()
        .create(Backend::Local, &spec)
        .expect("local source");
    let local = fetch_via(&demo, local).expect("local fetch");

    for ((c, l), truth) in cas.restored.iter().zip(&local.restored).zip(&demo.quants) {
        assert_eq!(c.idx, l.idx);
        assert_eq!(c.quant.data, truth.data, "cas restore vs ground truth");
        assert_eq!(c.quant.scales, truth.scales);
        assert_eq!(c.quant.data, l.quant.data, "cas vs local backend");
    }
    // a CAS GET has no shard fleet behind it; timings still cover every
    // chunk with real wire bytes
    assert_eq!(cas.wire_timings.len(), 4);
    for t in &cas.wire_timings {
        assert_eq!(t.shard, None);
        assert!(t.wire_bytes > 0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The paper's shared-system-prompt scenario: two prefixes with the
/// same seed share all leading chunks, so the second publish stores
/// zero new bytes for them — the store holds each shared object once.
#[test]
fn shared_prefix_head_is_stored_exactly_once() {
    let dir = tmpdir("dedup");
    let store = DirStore::open(&dir).expect("open");
    let (short, first) = publish_demo(&store, 7, 3);
    assert_eq!(first.objects_new, 6);
    let (long, second) = publish_demo(&store, 7, 6);
    // the 3 shared head chunks (x 2 resolutions) dedup against the
    // first publish; only the 3 new tail chunks write objects
    assert_eq!(second.objects_shared, 6, "shared system-prompt head must dedup");
    assert_eq!(second.objects_new, 6);
    assert!(second.bytes_shared > 0);

    let dedup = store_dedup(&store).expect("scan");
    assert_eq!(dedup.manifests, 2);
    assert_eq!(dedup.logical_objects, 18);
    assert_eq!(dedup.physical_objects, 12);
    assert!(dedup.ratio() > 1.0, "cross-prefix dedup ratio must exceed 1, got {}", dedup.ratio());

    // dedup is invisible to readers: both prefixes restore bit-exactly
    for demo in [&short, &long] {
        let cache = Arc::new(EdgeCache::new(64 << 20));
        let report = fetch_via(demo, Box::new(cas_source(&dir, demo, cache))).expect("fetch");
        assert_eq!(report.restored.len(), demo.hashes.len());
        for (d, truth) in report.restored.iter().zip(&demo.quants) {
            assert_eq!(d.quant.data, truth.data);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_edge_cache_serves_the_second_pass() {
    let dir = tmpdir("cache");
    let (demo, _) = publish_demo(&DirStore::open(&dir).expect("open"), 9, 4);
    let cache = Arc::new(EdgeCache::new(64 << 20));

    fetch_via(&demo, Box::new(cas_source(&dir, &demo, cache.clone()))).expect("cold pass");
    let cold = cache.stats();
    assert_eq!(cold.misses, 4, "cold pass GETs every object from the store");
    assert_eq!(cold.hits, 0);
    assert_eq!(cold.evictions, 0);
    assert!(cold.used_bytes > 0);

    let warm_report =
        fetch_via(&demo, Box::new(cas_source(&dir, &demo, cache.clone()))).expect("warm pass");
    let warm = cache.stats();
    assert_eq!(warm.hits, 4, "warm pass must be served from the edge cache");
    assert_eq!(warm.misses, 4, "no new store GETs on the warm pass");
    for (d, truth) in warm_report.restored.iter().zip(&demo.quants) {
        assert_eq!(d.quant.data, truth.data, "cached bytes restore bit-exactly");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Manifest robustness: every truncation fails typed, header corruption
/// fails typed, and a flipped chain hash is caught at source-open time
/// (the manifest no longer matches the requested chain).
#[test]
fn corrupt_manifests_fail_typed_never_panic() {
    let dir = tmpdir("manifest");
    let store = DirStore::open(&dir).expect("open");
    let (demo, _) = publish_demo(&store, 5, 2);
    let key = Manifest::key_for(&demo.hashes);
    let bytes = store.get_manifest(&key).expect("IO").expect("published");
    Manifest::decode(&bytes).expect("the untouched manifest decodes");

    for cut in 0..bytes.len() {
        match Manifest::decode(&bytes[..cut]) {
            Err(CodecError::Truncated(_) | CodecError::Malformed(_)) => {}
            Ok(_) => panic!("truncation at {cut} must not decode"),
            Err(e) => panic!("truncation at {cut}: unexpected error {e}"),
        }
    }
    // header corruption: magic and version are both load-bearing
    let mut bad_magic = bytes.clone();
    bad_magic[0] ^= 0xff;
    assert!(matches!(Manifest::decode(&bad_magic), Err(CodecError::Malformed(_))));
    let mut future_version = bytes.clone();
    future_version[4] = 9;
    assert!(matches!(Manifest::decode(&future_version), Err(CodecError::Malformed(_))));

    // a flipped chain hash still decodes (the bytes are self-
    // consistent) but can never serve the requested chain: layout is
    // magic(4) version(2) chunk_tokens(4) n_res(2) "144p"(6) "240p"(6)
    // n_chunks(4), so chunk 0's hash starts at offset 28
    let mut wrong_chain = bytes.clone();
    wrong_chain[28] ^= 0xff;
    let manifest = Manifest::decode(&wrong_chain).expect("self-consistent bytes decode");
    let err = CasSource::new(
        DirStore::open(&dir).expect("open"),
        manifest,
        demo.hashes.clone(),
        DEMO_LADDER,
        Arc::new(EdgeCache::new(1 << 20)),
    )
    .expect_err("a diverged chain must be rejected at open");
    match err {
        FetchError::Decode { detail, .. } => {
            assert!(detail.contains("diverges"), "unexpected detail: {detail}")
        }
        other => panic!("expected a typed Decode error, got {other}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Object robustness: any corrupted stored object is caught by digest
/// verification as a typed decode failure (never restored wrong), and
/// a deleted object surfaces as a typed transport failure naming the
/// dangling reference.
#[test]
fn corrupt_or_missing_objects_fail_typed() {
    let dir = tmpdir("objects");
    let (demo, _) = publish_demo(&DirStore::open(&dir).expect("open"), 13, 2);

    let objects_dir = dir.join("objects");
    let mut object_files: Vec<PathBuf> = std::fs::read_dir(&objects_dir)
        .expect("objects dir")
        .map(|e| e.expect("dir entry").path())
        .collect();
    object_files.sort();
    assert_eq!(object_files.len(), 4);

    // corrupt one byte in the middle of every object: the digest check
    // must catch each, whichever object the fixed-res fetch reads first
    let originals: Vec<Vec<u8>> =
        object_files.iter().map(|p| std::fs::read(p).expect("read object")).collect();
    for (path, orig) in object_files.iter().zip(&originals) {
        let mut bad = orig.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        std::fs::write(path, &bad).expect("corrupt object");
    }
    let cache = Arc::new(EdgeCache::new(1 << 20));
    let err = fetch_via(&demo, Box::new(cas_source(&dir, &demo, cache)))
        .expect_err("digest mismatch must fail the fetch");
    match err {
        FetchError::Decode { chunk, detail } => {
            assert!(chunk.is_some(), "the failure names the chunk it struck at");
            assert!(detail.contains("digest"), "unexpected detail: {detail}");
        }
        other => panic!("expected a typed Decode error, got {other}"),
    }

    // restore the bytes, then delete exactly the object the fixed-res
    // fetch reads (chunk 0 at 240p, per the manifest): a dangling
    // manifest reference is a transport-level miss, not a decode fault
    for (path, orig) in object_files.iter().zip(&originals) {
        std::fs::write(path, orig).expect("restore object");
    }
    let cache = Arc::new(EdgeCache::new(1 << 20));
    fetch_via(&demo, Box::new(cas_source(&dir, &demo, cache))).expect("restored store fetches");
    let store = DirStore::open(&dir).expect("open");
    let manifest = Manifest::decode(
        &store.get_manifest(&Manifest::key_for(&demo.hashes)).expect("IO").expect("published"),
    )
    .expect("decode");
    let res_pos =
        manifest.resolutions.iter().position(|r| r == "240p").expect("240p is published");
    let victim = manifest.chunks[0].objects[res_pos].key;
    std::fs::remove_file(objects_dir.join(victim.to_hex())).expect("delete referenced object");
    let cache = Arc::new(EdgeCache::new(1 << 20));
    let err = fetch_via(&demo, Box::new(cas_source(&dir, &demo, cache)))
        .expect_err("a dangling manifest ref must fail the fetch");
    match err {
        FetchError::Transport { chunk, detail, .. } => {
            assert_eq!(chunk, Some(0), "the miss names the chunk");
            assert!(detail.contains("not in the store"), "unexpected detail: {detail}");
        }
        other => panic!("expected a typed Transport error, got {other}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
