//! Cross-module integration tests: full data path (quantize -> layout ->
//! codec -> store -> fetch -> restore), engine x scheduler x fetcher
//! composition, and system-level invariants.

use kvfetcher::asic::{h20_table, DecodePool};
use kvfetcher::baselines::{SystemKind, SystemProfile};
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::codec::CodecConfig;
use kvfetcher::engine::{EngineConfig, EngineSim, ExecMode};
use kvfetcher::fetcher::{plan_fetch, FetchConfig, Fetcher};
use kvfetcher::kvstore::{prefix_hashes, StorageNode, StoredChunk, StoredVariant};
use kvfetcher::layout::{self, Resolution};
use kvfetcher::net::{BandwidthEstimator, BandwidthTrace, NetLink};
use kvfetcher::quant::{dequantize, quantize};
use kvfetcher::scheduler::SchedulerConfig;
use kvfetcher::tensor::KvCache;
use kvfetcher::trace::{generate, TraceConfig};
use kvfetcher::util::{proptest, Prng};

/// The full offline-compress -> store -> fetch -> restore path, via the
/// storage node, is bit-exact at every stored resolution.
#[test]
fn store_fetch_restore_roundtrip() {
    let mut rng = Prng::new(77);
    let kv = KvCache::synthetic(&mut rng, 128, 8, 8, 32, 0.95);
    let q = quantize(&kv);
    let resolutions = [
        Resolution { name: "240p", w: 64, h: 32 },
        Resolution { name: "1080p", w: 128, h: 64 },
    ];
    // pick the tiling on the smaller resolution so it fits both
    let intra = kvfetcher::engine::real::best_intra(&q, resolutions[0]);

    // offline: encode and register
    let mut node = StorageNode::new(128);
    let tokens: Vec<u32> = (0..128).map(|i| i * 31 + 7).collect();
    let hash = prefix_hashes(&tokens, 128)[0];
    let mut variants = Vec::new();
    for res in resolutions {
        let groups = layout::encode_chunk(&q, res, intra, &CodecConfig::lossless()).unwrap();
        variants.push(StoredVariant {
            resolution: res.name,
            n_frames: groups[0].layout.n_frames,
            total_bytes: groups.iter().map(|g| g.bytes.len()).sum(),
            group_bytes: groups.into_iter().map(|g| g.bytes).collect(),
        });
    }
    node.register(StoredChunk { hash, tokens: 128, scales: q.scales.clone(), variants });

    // online: prefix match then decode each variant
    assert_eq!(node.match_prefix(&tokens), vec![hash]);
    let chunk = node.get(hash).unwrap();
    for res in resolutions {
        let v = chunk.variant(res.name).unwrap();
        // rebuild EncodedGroups from stored bytes (meta is in-band)
        let mut restored = vec![0u8; q.data.len()];
        for gb in &v.group_bytes {
            let hdr = kvfetcher::codec::parse_header(gb).unwrap();
            let lay = layout::InterLayout::from_meta(&hdr.meta).unwrap();
            let mut fi = 0;
            kvfetcher::codec::decode_video_with(gb, |frame| {
                lay.restore_frame(frame, fi, &mut restored);
                fi += 1;
            })
            .unwrap();
        }
        assert_eq!(restored, q.data, "bit-exact restore at {}", res.name);
    }
    // and dequantization error stays within quantization bounds
    let back = dequantize(&q);
    let bound = q.scales.iter().cloned().fold(0.0f32, f32::max) * 0.5 + 1e-6;
    assert!(back.max_abs_diff(&kv) <= bound);
}

/// Every system completes every request; fetch requests reuse, and the
/// TTFT ordering of the paper holds on the default workload.
#[test]
fn engine_system_ordering() {
    let dev = DeviceSpec::h20();
    let perf = PerfModel::new(dev.clone(), ModelSpec::yi_34b());
    let trace = generate(&TraceConfig {
        seed: 5,
        n_requests: 20,
        rate: 0.1,
        ctx_min: 50_000,
        ctx_max: 150_000,
        reuse_frac: 1.0,
        reuse_threshold: 40_000,
        ..Default::default()
    });
    let mut means = std::collections::BTreeMap::new();
    for profile in SystemProfile::all(&dev) {
        let cfg = EngineConfig {
            sched: SchedulerConfig { fetching_aware: profile.fetching_aware, ..Default::default() },
            layerwise_pipeline: profile.fetching_aware,
            ..Default::default()
        };
        let mut eng =
            EngineSim::new(perf.clone(), profile.clone(), cfg, BandwidthTrace::constant(8.0));
        let rec = eng.run(&trace);
        assert_eq!(rec.records.len(), trace.len(), "{} must finish all", profile.name);
        let class = profile.kind != SystemKind::FullPrefill;
        means.insert(profile.name, rec.ttft_summary(Some(class)).mean);
    }
    assert!(means["KVFetcher"] < means["CacheGen"], "{means:?}");
    assert!(means["CacheGen"] < means["RawReuse"], "{means:?}");
    assert!(means["RawReuse"] < means["FullPrefill"], "{means:?}");
}

/// Property: across random bandwidths/contexts, KVFetcher's single-
/// request TTFT never loses to raw reuse and never loses badly to
/// CacheGen (within 5% numerical slack).
#[test]
fn prop_ttft_dominance() {
    let dev = DeviceSpec::h20();
    let perf = PerfModel::new(dev.clone(), ModelSpec::lwm_7b());
    let ttft = |profile: SystemProfile, trace: &BandwidthTrace, ctx: usize, reusable: usize| {
        Fetcher::builder()
            .profile(profile)
            .bandwidth(trace.clone())
            .for_perf(&perf)
            .build()
            .ttft(&perf, ctx, reusable, ExecMode::Analytic)
            .total()
    };
    proptest::check(91, 40, "ttft-dominance", |rng| {
        let bw = rng.f64_range(1.0, 40.0);
        let ctx = 20_000 + rng.below(180_000) as usize;
        let reusable = (ctx as f64 * 0.95) as usize;
        let trace = BandwidthTrace::constant(bw);
        let ours = ttft(SystemProfile::kvfetcher(), &trace, ctx, reusable);
        let raw = ttft(SystemProfile::raw_reuse(), &trace, ctx, reusable);
        let cg = ttft(SystemProfile::cachegen(&dev), &trace, ctx, reusable);
        if ours > raw * 1.05 {
            return Err(format!("ours {ours} vs raw {raw} at bw={bw} ctx={ctx}"));
        }
        if ours > cg * 1.05 {
            return Err(format!("ours {ours} vs cachegen {cg} at bw={bw} ctx={ctx}"));
        }
        Ok(())
    });
}

/// Property: fetch plans are well-formed under any bandwidth trace —
/// chunk stages ordered, monotone, and done_at >= every stage.
#[test]
fn prop_fetch_plan_wellformed() {
    proptest::check(93, 40, "fetch-plan-wellformed", |rng| {
        let profile = match rng.below(3) {
            0 => SystemProfile::kvfetcher(),
            1 => SystemProfile::cachegen(&DeviceSpec::a100()),
            _ => SystemProfile::raw_reuse(),
        };
        let trace = BandwidthTrace::jitter(rng.next_u64(), 8.0, 1.0, 40.0, 0.5, 1000.0);
        let mut link = NetLink::new(trace);
        let mut pool = DecodePool::new(1 + rng.below(14) as usize, h20_table());
        let mut est = BandwidthEstimator::new(0.5);
        let tokens = 1_000 + rng.below(150_000) as usize;
        let raw = tokens * 245_760;
        let cfg = FetchConfig { adaptive: rng.f64() < 0.5, ..Default::default() };
        let now = rng.f64_range(0.0, 100.0);
        let plan = plan_fetch(now, tokens, raw, &profile, &cfg, &mut link, &mut pool, &mut est);
        if plan.chunks.is_empty() {
            return Err("empty plan".into());
        }
        let mut prev_ts = now;
        for c in &plan.chunks {
            if c.trans_start + 1e-9 < prev_ts {
                return Err("transmissions must serialize".into());
            }
            if c.trans_end < c.trans_start
                || c.dec_start + 1e-9 < c.trans_end
                || c.dec_end < c.dec_start
            {
                return Err(format!("stage ordering violated: {c:?}"));
            }
            prev_ts = c.trans_start;
        }
        if plan.done_at + 1e-9 < plan.chunks.last().unwrap().dec_end {
            return Err("done_at before last decode".into());
        }
        Ok(())
    });
}

/// The engine respects memory: peak allocated KV never exceeds capacity.
#[test]
fn engine_memory_bounded() {
    let perf = PerfModel::new(DeviceSpec::l20(), ModelSpec::lwm_7b());
    let cfg = EngineConfig {
        kv_capacity_tokens: Some(300_000), // tight: forces admission waits
        ..Default::default()
    };
    let trace = generate(&TraceConfig {
        seed: 8,
        n_requests: 24,
        rate: 1.0, // burst
        ctx_min: 40_000,
        ctx_max: 120_000,
        reuse_frac: 0.5,
        ..Default::default()
    });
    let mut eng =
        EngineSim::new(perf, SystemProfile::kvfetcher(), cfg, BandwidthTrace::constant(16.0));
    let rec = eng.run(&trace);
    assert_eq!(rec.records.len(), trace.len(), "tight memory must not deadlock");
}

/// Fetching-aware scheduling is a strict improvement for non-reuse
/// requests across random traces (property over seeds).
#[test]
fn prop_fetching_aware_no_worse() {
    let perf = PerfModel::new(DeviceSpec::h20(), ModelSpec::yi_34b());
    proptest::check(95, 6, "fetching-aware-no-worse", |rng| {
        let trace = generate(&TraceConfig {
            seed: rng.next_u64(),
            n_requests: 16,
            rate: 0.1,
            ctx_min: 4_000,
            ctx_max: 100_000,
            reuse_frac: 1.0,
            reuse_threshold: 40_000,
            ..Default::default()
        });
        if !trace.iter().any(|r| r.is_fetch()) {
            return Ok(()); // nothing to compare
        }
        let run = |aware: bool| {
            let mut p = SystemProfile::kvfetcher();
            p.fetching_aware = aware;
            let cfg = EngineConfig {
                sched: SchedulerConfig { fetching_aware: aware, ..Default::default() },
                layerwise_pipeline: aware,
                ..Default::default()
            };
            EngineSim::new(perf.clone(), p, cfg, BandwidthTrace::constant(2.0)).run(&trace)
        };
        let aware = run(true).ttft_summary(Some(false));
        let blocked = run(false).ttft_summary(Some(false));
        if aware.n == 0 {
            return Ok(());
        }
        if aware.mean > blocked.mean * 1.10 {
            return Err(format!(
                "aware {:.2}s should not exceed blocking {:.2}s",
                aware.mean, blocked.mean
            ));
        }
        Ok(())
    });
}
