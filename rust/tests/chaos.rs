//! Chaos mode: seeded fault-scenario generation and fleet convergence
//! (ISSUE 10).
//!
//! Acceptance contracts:
//! * schedule determinism: the same `ChaosSpec` expands to an
//!   identical event list AND a byte-identical `chaos.json`; distinct
//!   seeds produce distinct schedules; `max_events` truncates to an
//!   exact prefix of the full expansion (the shrinking knob);
//! * `ShardMap` version transitions hold their invariants under
//!   *randomized* grow/shrink walks (proptest-style loop over the
//!   repo's own PRNG, arbitrary — not just max-slot — removals):
//!   replica sets never contain a duplicate slot, every chunk stays
//!   placeable mid-transition via `read_order` (new ring first, old
//!   holders appended, all within `union_slots`), and `moved()` is
//!   exactly the set of chunks whose replica set changed;
//! * end to end, a `ChaosRunner` executes a seeded schedule against a
//!   live loopback fleet and the run holds every invariant: each
//!   completed fetch restores bit-identically, every kill re-converges
//!   through repair and every grow/shrink through rebalance, and obs
//!   counters stay consistent — with each injected event leaving an
//!   instant on the dedicated chaos trace track.

use std::collections::BTreeSet;
use std::sync::Arc;

use kvfetcher::obs::{Track, TraceRecorder};
use kvfetcher::service::{
    ChaosEventKind, ChaosFleetSpec, ChaosRunner, ChaosSpec, MapTransition, Placement, ShardMap,
};
use kvfetcher::util::json::Json;
use kvfetcher::util::Prng;

#[test]
fn same_seed_expands_to_identical_schedule_and_json() {
    for seed in [0u64, 1, 42, 0xDEAD_BEEF, u64::MAX] {
        let spec = ChaosSpec { seed, duration_secs: 20.0, ..Default::default() };
        let a = spec.expand();
        let b = spec.expand();
        assert_eq!(a, b, "seed {seed}: expansion must be pure in the spec");
        assert_eq!(a.seed, seed);
        let ja = a.to_json(&spec).to_string();
        let jb = b.to_json(&spec).to_string();
        assert_eq!(ja, jb, "seed {seed}: chaos.json must be byte-identical");
        // the document round-trips through the repo's own parser
        let parsed = Json::parse(&ja).expect("chaos.json parses");
        assert_eq!(parsed.get("seed").and_then(Json::as_f64), Some(seed as f64));
        assert_eq!(
            parsed.get("n_events").and_then(Json::as_usize),
            Some(a.events.len()),
            "n_events echoes the schedule length"
        );
        let events = parsed.get("events").and_then(Json::as_arr).expect("events array");
        assert_eq!(events.len(), a.events.len());
        for (ev, doc) in a.events.iter().zip(events) {
            assert_eq!(doc.get("kind").and_then(Json::as_str), Some(ev.kind.name()));
            assert_eq!(doc.get("at_ms").and_then(Json::as_usize), Some(ev.at_ms as usize));
        }
    }
}

#[test]
fn distinct_seeds_expand_to_distinct_schedules() {
    let base = ChaosSpec { duration_secs: 30.0, ..Default::default() };
    let schedules: Vec<_> = [1u64, 2, 3, 99, 1234]
        .into_iter()
        .map(|seed| ChaosSpec { seed, ..base.clone() }.expand())
        .collect();
    for (i, a) in schedules.iter().enumerate() {
        for b in &schedules[i + 1..] {
            assert_ne!(
                a.events, b.events,
                "seeds {} and {} must not collide on a 30s horizon",
                a.seed, b.seed
            );
        }
    }
}

#[test]
fn max_events_shrinks_to_an_exact_prefix() {
    let full = ChaosSpec { seed: 17, duration_secs: 25.0, ..Default::default() };
    let all = full.expand();
    assert!(all.events.len() >= 4, "horizon long enough to shrink meaningfully");
    for cap in [0, 1, 2, all.events.len() - 1, all.events.len(), all.events.len() + 5] {
        let capped = ChaosSpec { max_events: Some(cap), ..full.clone() }.expand();
        let want = cap.min(all.events.len());
        assert_eq!(capped.events.len(), want, "cap {cap}");
        assert_eq!(
            &capped.events[..],
            &all.events[..want],
            "cap {cap}: shrinking must keep an exact prefix, not redraw"
        );
    }
}

#[test]
fn schedules_never_emit_events_the_fleet_cannot_absorb() {
    // weights left at default: every kind eligible — the expansion
    // itself must keep kills off replication-1 fleets and keep the
    // simulated size within [shards, shards + cap]
    for (replication, seed) in [(1usize, 5u64), (2, 6), (3, 7)] {
        let spec = ChaosSpec {
            seed,
            duration_secs: 40.0,
            fleet: ChaosFleetSpec { shards: 3, replication, placement: Placement::RoundRobin },
            ..Default::default()
        };
        let mut size = spec.fleet.shards;
        for ev in &spec.expand().events {
            match ev.kind {
                ChaosEventKind::KillShard { shard, .. } => {
                    assert!(replication >= 2, "kills need a surviving replica");
                    assert!(shard < size);
                }
                ChaosEventKind::BusyStorm { shard, .. }
                | ChaosEventKind::AcceptDelay { shard, .. }
                | ChaosEventKind::ThrottleSwap { shard, .. } => assert!(shard < size),
                ChaosEventKind::Grow => size += 1,
                ChaosEventKind::Shrink { slot } => {
                    assert_eq!(slot, size - 1, "runner shrinks retire the max slot");
                    size -= 1;
                }
                ChaosEventKind::LoadBurst { .. } => {}
            }
            assert!(size >= spec.fleet.shards, "never shrinks below the spec fleet");
        }
    }
}

/// One randomized grow/shrink walk: at every step, pair the old and
/// new maps into a `MapTransition` and check the placement invariants
/// over a synthetic chunk chain.
fn transition_walk(rng: &mut Prng, placement: Placement) {
    let n0 = 2 + rng.below(4) as usize;
    let replication = 1 + rng.below(3) as usize;
    let mut map = ShardMap::with_replication(n0, placement, replication);
    let hashes: Vec<u64> = (0..32).map(|_| rng.next_u64()).collect();
    for _ in 0..12 {
        let old = map.clone();
        // arbitrary-slot shrinks here, unlike the runner's dense walk
        let new = if map.n_shards() >= 2 && rng.below(2) == 0 {
            let victim = map.shards()[rng.below(map.n_shards() as u64) as usize];
            map.shrunk(victim).expect("victim is in the ring and not last")
        } else {
            map.grown()
        };
        assert_eq!(new.version(), old.version() + 1, "every step bumps the version");
        let t = MapTransition::new(old.clone(), new.clone()).expect("version raised");
        let union: BTreeSet<usize> = t.union_slots().into_iter().collect();
        for (i, &h) in hashes.iter().enumerate() {
            for m in [&old, &new] {
                let reps = m.replicas_of(i, h);
                let distinct: BTreeSet<usize> = reps.iter().copied().collect();
                assert_eq!(distinct.len(), reps.len(), "replica sets never collide");
                assert_eq!(reps.len(), m.replication());
                assert!(reps.iter().all(|s| m.contains(*s)), "replicas are ring members");
            }
            let order = t.read_order(i, h);
            assert!(!order.is_empty(), "every chunk stays placeable mid-transition");
            assert_eq!(
                &order[..new.replication()],
                &new.replicas_of(i, h)[..],
                "read order tries the new ring first"
            );
            let in_order: BTreeSet<usize> = order.iter().copied().collect();
            assert_eq!(in_order.len(), order.len(), "read order never repeats a slot");
            assert!(order.iter().all(|s| union.contains(s)), "read order stays in the union");
            for s in old.replicas_of(i, h) {
                assert!(in_order.contains(&s), "old holders stay reachable mid-transition");
            }
            assert_eq!(
                t.moved(i, h),
                old.replicas_of(i, h) != new.replicas_of(i, h),
                "moved() is exactly the set whose replica set changed"
            );
        }
        map = new;
    }
}

#[test]
fn shard_map_transitions_hold_invariants_under_random_walks() {
    // proptest-style: many independent seeded walks, both placements
    let mut rng = Prng::new(0x5EED_CA05);
    for _ in 0..40 {
        transition_walk(&mut rng, Placement::RoundRobin);
        transition_walk(&mut rng, Placement::ByHash);
    }
}

#[test]
fn chaos_runner_holds_every_invariant_on_a_seeded_scenario() {
    // small but non-trivial: the first six events of a dense schedule
    // against a 3-shard r2 fleet, with the trace recorder attached
    let spec = ChaosSpec {
        seed: 1001,
        duration_secs: 6.0,
        events_per_sec: 2.0,
        n_chunks: 4,
        chunk_tokens: 24,
        max_events: Some(6),
        ..Default::default()
    };
    let schedule = spec.expand();
    assert!(!schedule.events.is_empty());
    let rec = TraceRecorder::new(1 << 14);
    let runner = ChaosRunner::new(spec).expect("loopback fleet spawns");
    let report = runner.with_recorder(Some(Arc::clone(&rec))).run(&schedule);
    assert!(
        report.ok(),
        "seed {} must hold every invariant, got: {:#?}",
        report.seed,
        report.violations
    );
    assert_eq!(report.events_run, schedule.events.len());
    // baseline + post-chaos fetches always run, plus per-event checks
    assert!(report.fetches_verified >= 2, "got {}", report.fetches_verified);
    // every injected event left an instant on the chaos track
    let chaos_marks =
        rec.events().iter().filter(|e| e.track == Track::Chaos).count();
    assert_eq!(chaos_marks, report.events_run, "one chaos instant per executed event");
    // and the kill/rebalance gates that ran are accounted
    let kills = schedule
        .events
        .iter()
        .filter(|e| matches!(e.kind, ChaosEventKind::KillShard { .. }))
        .count();
    let moves = schedule
        .events
        .iter()
        .filter(|e| matches!(e.kind, ChaosEventKind::Grow | ChaosEventKind::Shrink { .. }))
        .count();
    assert_eq!(report.repairs_converged, kills);
    assert_eq!(report.rebalances_converged, moves);
}

#[test]
fn chaos_runner_converges_under_by_hash_placement() {
    let spec = ChaosSpec {
        seed: 2002,
        duration_secs: 4.0,
        events_per_sec: 2.0,
        fleet: ChaosFleetSpec { shards: 3, replication: 2, placement: Placement::ByHash },
        n_chunks: 3,
        chunk_tokens: 24,
        max_events: Some(4),
        ..Default::default()
    };
    let schedule = spec.expand();
    let report = ChaosRunner::new(spec).expect("loopback fleet spawns").run(&schedule);
    assert!(
        report.ok(),
        "seed {} must hold every invariant, got: {:#?}",
        report.seed,
        report.violations
    );
    assert_eq!(report.events_run, schedule.events.len());
}
