//! Elastic fleet: versioned shard map, live rebalance, and write
//! placement (ISSUE 9).
//!
//! Acceptance contracts:
//! * grow N -> N+1: a `Rebalancer` pass over the map transition copies
//!   every chunk whose replica set changed onto its new-ring replicas,
//!   the post-pass scan converges (holders cover the new map — surplus
//!   copies on old-only slots are allowed, they age out of the LRU),
//!   and a fetch through the grown fleet restores bit-identically;
//! * removal is symmetric: shrink N -> N-1, migrate, and the surviving
//!   fleet alone serves a bit-identical restore;
//! * a fetch issued *mid-migration* (transition attached, nothing
//!   copied yet) restores bit-identically by falling back from
//!   new-ring replicas to old-ring holders;
//! * a write-through put with a dead replica does not abort: surviving
//!   replicas hold the chunk and the typed error names the dead shard;
//! * `WritePolicy::LeastUsed` ranks write candidates by live
//!   `used_bytes + inflight_bytes` from wire `NodeStats`.

use std::collections::BTreeMap;

use kvfetcher::asic::{h20_table, DecodePool};
use kvfetcher::baselines::SystemProfile;
use kvfetcher::fetcher::{
    ExecMode, FetchConfig, FetchReport, FetchRequest, Fetcher, ReadPolicy, ResolutionPolicy,
};
use kvfetcher::kvstore::StorageNode;
use kvfetcher::net::BandwidthTrace;
use kvfetcher::service::{
    demo_prefix, Backend, DemoPrefix, MapTransition, Placement, Rebalancer, RemoteSource,
    RetryPolicy, ServerConfig, ShardMap, ShardRouter, SourceRegistry, SourceSpec, StorageServer,
    StoreClient, WritePolicy, DEMO_HEADS, DEMO_HEAD_DIM, DEMO_LADDER, DEMO_PLANES,
};

/// Spawn one server per shard of `map`, populated in-process with the
/// chunks that shard's replica set owns under `map`.
fn launch(demo: &DemoPrefix, map: &ShardMap) -> (Vec<StorageServer>, Vec<String>) {
    let mut nodes: Vec<StorageNode> =
        (0..map.n_shards()).map(|_| StorageNode::new(demo.chunk_tokens)).collect();
    for (i, chunk) in demo.chunks.iter().enumerate() {
        for shard in map.replicas_of(i, chunk.hash) {
            assert!(nodes[shard].register(chunk.clone()).stored);
        }
    }
    let mut servers = Vec::new();
    let mut addrs = Vec::new();
    for node in nodes {
        let server = StorageServer::spawn("127.0.0.1:0", node, ServerConfig::default())
            .expect("bind");
        addrs.push(server.local_addr().to_string());
        servers.push(server);
    }
    (servers, addrs)
}

/// Spawn one empty server (a node joining the fleet with no data).
fn spawn_empty(demo: &DemoPrefix) -> (StorageServer, String) {
    let node = StorageNode::new(demo.chunk_tokens);
    let server = StorageServer::spawn("127.0.0.1:0", node, ServerConfig::default()).expect("bind");
    let addr = server.local_addr().to_string();
    (server, addr)
}

fn demo_request(demo: &DemoPrefix) -> FetchRequest {
    let total_tokens = demo.hashes.len() * demo.chunk_tokens;
    FetchRequest::new(total_tokens, total_tokens * DEMO_PLANES * DEMO_HEADS * DEMO_HEAD_DIM * 2)
        .with_hashes(demo.hashes.clone())
        .resolution(ResolutionPolicy::Fixed(0))
        .exec(ExecMode::Pipelined)
}

fn demo_fetcher(demo: &DemoPrefix, replication: usize) -> Fetcher {
    Fetcher::builder()
        .profile(SystemProfile::kvfetcher())
        .fetch_config(FetchConfig { chunk_tokens: demo.chunk_tokens, ..Default::default() })
        .bandwidth(BandwidthTrace::constant(8.0))
        .decode_pool(DecodePool::new(7, h20_table()))
        .replication(replication)
        .read_policy(ReadPolicy::PrimaryFirst)
        .build()
}

/// Bit-exactness assertion shared by every fetch in this file.
fn assert_bit_exact(report: &FetchReport, demo: &DemoPrefix, label: &str) {
    assert_eq!(report.restored.len(), demo.hashes.len(), "{label}");
    for (d, q) in report.restored.iter().zip(&demo.quants) {
        assert_eq!(d.quant.data, q.data, "{label}: restore must be bit-exact");
        assert_eq!(d.quant.scales, q.scales, "{label}");
    }
}

/// One pipelined fetch through a TCP fleet built from `addrs` with a
/// dense replicated map (the post-transition steady state).
fn steady_state_fetch(demo: &DemoPrefix, addrs: &[String], replication: usize) -> FetchReport {
    let mut spec = SourceSpec::new(demo.hashes.clone(), DEMO_LADDER);
    spec.addrs = addrs.to_vec();
    spec.placement = Placement::RoundRobin;
    spec.replication = replication;
    spec.tokens = demo.tokens.clone();
    spec.chunk_tokens = demo.chunk_tokens;
    spec.retry = RetryPolicy { max_busy_retries: 6, min_backoff_ms: 2, max_backoff_ms: 50 };
    let source = SourceRegistry::with_defaults().create(Backend::Tcp, &spec).expect("tcp source");
    let fetcher = demo_fetcher(demo, replication);
    let mut session = fetcher.session(demo_request(demo)).with_source(source);
    session.run().expect("steady-state fetch completes");
    let report = session.take_report().expect("report stored");
    assert_bit_exact(&report, demo, "steady-state");
    report
}

/// Over-the-wire holder sets: which of `addrs` hold each chunk.
fn holder_sets(demo: &DemoPrefix, addrs: &[String]) -> Vec<Vec<usize>> {
    let clients: Vec<StoreClient> =
        addrs.iter().map(|a| StoreClient::connect(a).expect("connect")).collect();
    demo.hashes
        .iter()
        .map(|&h| {
            (0..addrs.len())
                .filter(|&s| clients[s].has_chunks(&[h]).expect("probe")[0])
                .collect()
        })
        .collect()
}

/// Acceptance: add a third node to a 2-shard replicated fleet, migrate,
/// and converge — every chunk's holder set covers the new map's replica
/// set, the grown fleet serves a bit-identical restore, and a second
/// migration pass is a no-op.
#[test]
fn growing_the_fleet_converges_and_restores_bit_identically() {
    let demo = demo_prefix(211, 6, 32);
    let old = ShardMap::with_replication(2, Placement::RoundRobin, 2);
    let (servers, mut addrs) = launch(&demo, &old);
    let new = old.grown();
    assert_eq!((new.version(), new.n_shards()), (2, 3));
    let (joined, joined_addr) = spawn_empty(&demo);
    addrs.push(joined_addr);

    let t = MapTransition::new(old, new.clone()).expect("grown raises the version");
    // chunks whose new-ring replica set includes the joined slot move
    let must_move = (0..demo.hashes.len())
        .filter(|&i| new.replicas_of(i, demo.hashes[i]).contains(&2))
        .count();
    assert!(must_move >= 2, "growth must move several chunks");

    let router =
        ShardRouter::connect_replicated(&addrs, Placement::RoundRobin, 2).expect("connect union");
    let rb = Rebalancer::new(router, t).expect("union covered");
    let before = rb.scan(&demo.hashes);
    assert!(!before.converged(), "the joined node starts empty");
    assert_eq!(before.pending(), must_move);

    let report = rb.migrate(&demo.hashes);
    assert!(report.converged(), "failed: {:?}", report.failed);
    assert_eq!(report.migrated.len(), must_move);
    assert!(report.migrated.iter().all(|a| a.to == 2), "only the joined slot was short");
    assert!(rb.scan(&demo.hashes).converged(), "new map must serve everything");

    // holder sets cover the new replica sets; surplus copies on the old
    // ring are allowed (no delete verb — they age out of the LRU)
    for (i, holders) in holder_sets(&demo, &addrs).iter().enumerate() {
        for slot in new.replicas_of(i, demo.hashes[i]) {
            assert!(holders.contains(&slot), "chunk {i} must land on new-ring slot {slot}");
        }
    }

    // the grown fleet serves the whole prefix bit-identically
    steady_state_fetch(&demo, &addrs, 2);

    // idempotent: a second pass copies nothing
    let again = rb.migrate(&demo.hashes);
    assert!(again.migrated.is_empty() && again.failed.is_empty());

    joined.shutdown();
    for s in servers {
        s.shutdown();
    }
}

/// Removal is symmetric: migrate chunks off the leaving slot, shut it
/// down, and the survivors alone serve a bit-identical restore.
#[test]
fn removing_a_node_migrates_its_chunks_to_the_survivors() {
    let demo = demo_prefix(223, 6, 32);
    let old = ShardMap::with_replication(3, Placement::RoundRobin, 2);
    let (mut servers, addrs) = launch(&demo, &old);
    let new = old.shrunk(1).expect("slot 1 is removable");
    assert_eq!((new.version(), new.n_shards()), (2, 2));
    assert_eq!(new.shards(), &[0, 2], "survivors keep their slot ids");

    let t = MapTransition::new(old, new.clone()).expect("shrunk raises the version");
    let router =
        ShardRouter::connect_replicated(&addrs, Placement::RoundRobin, 2).expect("connect union");
    let rb = Rebalancer::new(router, t).expect("union covered");
    let report = rb.migrate(&demo.hashes);
    assert!(report.converged(), "failed: {:?}", report.failed);
    assert!(rb.scan(&demo.hashes).converged());
    // every copy targeted a survivor, never the leaving slot
    assert!(report.migrated.iter().all(|a| a.to != 1));

    // with replication 2 over 2 survivors, both must hold every chunk
    for (i, holders) in holder_sets(&demo, &addrs).iter().enumerate() {
        assert!(
            holders.contains(&0) && holders.contains(&2),
            "chunk {i} must sit on both survivors: {holders:?}"
        );
    }

    // the leaving node shuts down; the survivors alone serve the prefix
    servers.remove(1).shutdown();
    let survivor_addrs = vec![addrs[0].clone(), addrs[2].clone()];
    steady_state_fetch(&demo, &survivor_addrs, 2);
    for s in servers {
        s.shutdown();
    }
}

/// Acceptance: a fetch issued *during* migration — transition attached,
/// nothing copied yet — restores bit-identically by falling back from
/// the (empty) new-ring replicas to the old-ring holders; after the
/// migration the same transition-aware source reads from the new ring.
#[test]
fn mid_migration_fetch_reads_through_either_map() {
    let demo = demo_prefix(227, 6, 32);
    let old = ShardMap::new(1, Placement::RoundRobin);
    let (servers, mut addrs) = launch(&demo, &old);
    let new = old.grown();
    let (joined, joined_addr) = spawn_empty(&demo);
    addrs.push(joined_addr);
    let t = MapTransition::new(old, new).expect("grown raises the version");

    let transition_fetch = |label: &str| -> FetchReport {
        let router =
            ShardRouter::connect_replicated(&addrs, Placement::RoundRobin, 1).expect("connect");
        let source = RemoteSource::new(router, demo.hashes.clone(), DEMO_LADDER)
            .with_retry(RetryPolicy { max_busy_retries: 6, min_backoff_ms: 2, max_backoff_ms: 50 })
            .with_transition(Some(t.clone()));
        let fetcher = demo_fetcher(&demo, 1);
        let mut session = fetcher.session(demo_request(&demo)).with_source(Box::new(source));
        session.run().unwrap_or_else(|e| panic!("{label} fetch must complete: {e}"));
        let report = session.take_report().expect("report stored");
        assert_bit_exact(&report, &demo, label);
        report
    };

    // before any chunk moves: every chunk still comes off the old slot
    let before = transition_fetch("mid-migration");
    let served: BTreeMap<usize, usize> = before.wire_timings.iter().fold(
        BTreeMap::new(),
        |mut h, w| {
            *h.entry(w.shard.expect("tcp names the shard")).or_insert(0) += 1;
            h
        },
    );
    assert_eq!(served.get(&0), Some(&demo.hashes.len()), "old slot serves all: {served:?}");

    // migrate, then the same transition-aware read path prefers the new
    // ring — chunks whose new primary is the joined slot move over
    let router =
        ShardRouter::connect_replicated(&addrs, Placement::RoundRobin, 1).expect("connect");
    let rb = Rebalancer::new(router, t.clone()).expect("union covered");
    let report = rb.migrate(&demo.hashes);
    assert!(report.converged(), "failed: {:?}", report.failed);
    let after = transition_fetch("post-migration");
    for w in &after.wire_timings {
        let new_primary = t.new.replicas_of(w.idx, demo.hashes[w.idx])[0];
        assert_eq!(w.shard, Some(new_primary), "chunk {} must read the new ring", w.idx);
    }

    joined.shutdown();
    for s in servers {
        s.shutdown();
    }
}

/// Bugfix acceptance: a write-through put with one dead replica keeps
/// writing — the surviving replicas hold the chunk, the per-replica
/// outcome distinguishes them, and the typed error names the dead
/// shard.
#[test]
fn partial_write_through_survives_and_names_the_dead_shard() {
    let demo = demo_prefix(229, 2, 32);
    // two empty shards, replication 2: both are write targets
    let a = spawn_empty(&demo);
    let b = spawn_empty(&demo);
    let addrs = vec![a.1.clone(), b.1.clone()];
    // kill shard 1 before the put; lenient connect keeps slot 1 routable
    b.0.shutdown();
    let (router, dead) =
        ShardRouter::connect_lenient(&addrs, Placement::RoundRobin, 2).expect("lenient");
    assert_eq!(dead, vec![1]);

    let out = router.put_chunk(0, &demo.chunks[0]);
    assert!(!out.all_stored());
    assert_eq!(out.stored_shards(), vec![0], "the live replica must still be written");
    assert_eq!(out.failed_shards(), vec![1]);
    let err = out.require_stored().expect_err("a partial write is an error");
    let msg = err.to_string();
    assert!(msg.contains("[1]"), "error must name the dead shard: {msg}");
    assert!(msg.contains("[0]"), "error must name the surviving replicas: {msg}");

    // the surviving replica really holds the chunk, over the wire
    let live = StoreClient::connect(&addrs[0]).expect("connect");
    assert!(live.has_chunks(&[demo.hashes[0]]).expect("probe")[0]);
    a.0.shutdown();
}

/// `WritePolicy::LeastUsed` consults live `NodeStats`: with one loaded
/// and one empty candidate, the empty shard is written first; the
/// default ring-successor order is preserved under `RingSuccessor`.
#[test]
fn least_used_write_policy_prefers_the_emptier_shard() {
    let demo = demo_prefix(233, 4, 32);
    // shard 0 pre-loaded with every chunk, shard 1 empty
    let mut loaded = StorageNode::new(demo.chunk_tokens);
    for c in &demo.chunks {
        assert!(loaded.register(c.clone()).stored);
    }
    let s0 = StorageServer::spawn("127.0.0.1:0", loaded, ServerConfig::default()).expect("bind");
    let (s1, addr1) = spawn_empty(&demo);
    let addrs = vec![s0.local_addr().to_string(), addr1];

    let router =
        ShardRouter::connect_replicated(&addrs, Placement::RoundRobin, 2).expect("connect");
    assert_eq!(router.write_order(&[0, 1]), vec![0, 1], "ring order by default");
    let router = router.with_write_policy(WritePolicy::LeastUsed);
    assert_eq!(
        router.write_order(&[0, 1]),
        vec![1, 0],
        "least-used must rank the empty shard first"
    );
    s0.shutdown();
    s1.shutdown();
}
