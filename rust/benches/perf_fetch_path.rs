//! §Perf — L3 coordinator hot path: fetch planning, scheduler
//! admission, paged allocation, and full-engine simulation throughput.
//! Target (DESIGN.md §7): >= 100k scheduling/fetch events per second.
//!
//! Run: `cargo bench --bench perf_fetch_path -- [--quick] [--out file]`
//! Writes the run as `BENCH_perf_fetch_path.json` (schema version 1,
//! validated by `python/tools/check_bench_schema.py` in the CI
//! `bench-trajectory` job); `--quick` shrinks iteration counts for CI.

use std::collections::BTreeMap;

use kvfetcher::asic::{h20_table, DecodePool};
use kvfetcher::baselines::SystemProfile;
use kvfetcher::cache::BlockAllocator;
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::engine::{EngineConfig, EngineSim};
use kvfetcher::fetcher::{plan_fetch, select_resolution, FetchConfig};
use kvfetcher::net::{BandwidthEstimator, BandwidthTrace, NetLink};
use kvfetcher::trace::{generate, TraceConfig};
use kvfetcher::util::json::Json;
use kvfetcher::util::table::markdown;

fn parse_flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

/// The `BENCH_*.json` perf-trajectory point of a micro-bench run
/// (schema version 1, `points` variant — validated by
/// `python/tools/check_bench_schema.py`).
fn bench_json(bench: &str, points: &[(String, f64, &'static str)]) -> Json {
    let arr = points
        .iter()
        .map(|(name, value, unit)| {
            let mut p = BTreeMap::new();
            p.insert("name".into(), Json::Str(name.clone()));
            p.insert("value".into(), Json::Num(*value));
            p.insert("unit".into(), Json::Str((*unit).into()));
            Json::Obj(p)
        })
        .collect();
    let mut o = BTreeMap::new();
    o.insert("bench".into(), Json::Str(bench.into()));
    o.insert("schema_version".into(), Json::Num(1.0));
    o.insert("points".into(), Json::Arr(arr));
    Json::Obj(o)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    println!("# perf_fetch_path — coordinator hot-path throughput\n");
    let mut rows = Vec::new();
    let mut points: Vec<(String, f64, &'static str)> = Vec::new();

    // Alg. 1 resolution selection rate
    let pool = DecodePool::new(7, h20_table());
    let n = if quick { 200_000 } else { 1_000_000 };
    let t0 = std::time::Instant::now();
    let mut acc = 0usize;
    for i in 0..n {
        acc += select_resolution(2.0 + (i % 30) as f64, 200_000_000, &pool, 0.0, 1.0);
    }
    std::hint::black_box(acc);
    let dt = t0.elapsed().as_secs_f64();
    rows.push(vec!["Alg.1 select_resolution".into(), format!("{:.1}M ops/s", n as f64 / dt / 1e6)]);
    points.push(("select_resolution".into(), n as f64 / dt / 1e6, "Mops/s"));

    // fetch planning rate (10-chunk plans)
    let profile = SystemProfile::kvfetcher();
    let cfg = FetchConfig::default();
    let perf = PerfModel::new(DeviceSpec::h20(), ModelSpec::yi_34b());
    let raw = perf.kv_bytes(100_000);
    let t0 = std::time::Instant::now();
    let plans = if quick { 4_000 } else { 20_000 };
    for i in 0..plans {
        let mut link = NetLink::new(BandwidthTrace::constant(16.0));
        let mut p = DecodePool::new(14, h20_table());
        let mut est = BandwidthEstimator::new(0.5);
        std::hint::black_box(plan_fetch(
            i as f64, 100_000, raw, &profile, &cfg, &mut link, &mut p, &mut est,
        ));
    }
    let dt = t0.elapsed().as_secs_f64();
    rows.push(vec![
        "plan_fetch (10 chunks, fresh state)".into(),
        format!(
            "{:.0}K plans/s ({:.0}K chunk-events/s)",
            plans as f64 / dt / 1e3,
            plans as f64 * 10.0 / dt / 1e3
        ),
    ]);
    points.push(("plan_fetch".into(), plans as f64 / dt / 1e3, "Kplans/s"));
    points.push(("plan_fetch_chunk_events".into(), plans as f64 * 10.0 / dt / 1e3, "Kevents/s"));

    // allocator churn
    let mut alloc = BlockAllocator::new(4096, 256);
    let t0 = std::time::Instant::now();
    let rounds = if quick { 50_000 } else { 200_000 };
    for _ in 0..rounds {
        let b = alloc.alloc(8).unwrap();
        alloc.release_all(&b);
    }
    let dt = t0.elapsed().as_secs_f64();
    rows.push(vec![
        "paged alloc/release (8 blocks)".into(),
        format!("{:.1}M ops/s", rounds as f64 / dt / 1e6),
    ]);
    points.push(("alloc_release".into(), rounds as f64 / dt / 1e6, "Mops/s"));

    // full engine sim throughput (requests simulated per second)
    let n_requests = if quick { 64 } else { 256 };
    let trace = generate(&TraceConfig { n_requests, rate: 1.0, ..Default::default() });
    let t0 = std::time::Instant::now();
    let mut eng = EngineSim::new(
        perf.clone(),
        SystemProfile::kvfetcher(),
        EngineConfig::default(),
        BandwidthTrace::constant(16.0),
    );
    let rec = eng.run(&trace);
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(rec.records.len(), trace.len());
    rows.push(vec![
        format!("EngineSim end-to-end ({n_requests} reqs)"),
        format!("{:.0} simulated reqs/s", trace.len() as f64 / dt),
    ]);
    points.push(("enginesim_requests".into(), trace.len() as f64 / dt, "reqs/s"));

    println!("{}", markdown(&["hot path", "throughput"], &rows));
    println!("target (DESIGN.md §7): fetch-path event loop >= 100k events/s");

    let out = parse_flag(&args, "--out").unwrap_or_else(|| "BENCH_perf_fetch_path.json".into());
    let json = bench_json("perf_fetch_path", &points);
    if let Err(e) = std::fs::write(&out, json.to_string() + "\n") {
        eprintln!("cannot write {out}: {e}");
        std::process::exit(1);
    }
    println!("wrote {out}");
}
