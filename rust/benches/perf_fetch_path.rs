//! §Perf — L3 coordinator hot path: fetch planning, scheduler
//! admission, paged allocation, and full-engine simulation throughput.
//! Target (DESIGN.md §7): >= 100k scheduling/fetch events per second.

use kvfetcher::asic::{h20_table, DecodePool};
use kvfetcher::baselines::SystemProfile;
use kvfetcher::cache::BlockAllocator;
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::engine::{EngineConfig, EngineSim};
use kvfetcher::fetcher::{plan_fetch, select_resolution, FetchConfig};
use kvfetcher::net::{BandwidthEstimator, BandwidthTrace, NetLink};
use kvfetcher::trace::{generate, TraceConfig};
use kvfetcher::util::table::markdown;

fn main() {
    println!("# perf_fetch_path — coordinator hot-path throughput\n");
    let mut rows = Vec::new();

    // Alg. 1 resolution selection rate
    let pool = DecodePool::new(7, h20_table());
    let n = 1_000_000;
    let t0 = std::time::Instant::now();
    let mut acc = 0usize;
    for i in 0..n {
        acc += select_resolution(2.0 + (i % 30) as f64, 200_000_000, &pool, 0.0, 1.0);
    }
    std::hint::black_box(acc);
    let dt = t0.elapsed().as_secs_f64();
    rows.push(vec!["Alg.1 select_resolution".into(), format!("{:.1}M ops/s", n as f64 / dt / 1e6)]);

    // fetch planning rate (10-chunk plans)
    let profile = SystemProfile::kvfetcher();
    let cfg = FetchConfig::default();
    let perf = PerfModel::new(DeviceSpec::h20(), ModelSpec::yi_34b());
    let raw = perf.kv_bytes(100_000);
    let t0 = std::time::Instant::now();
    let plans = 20_000;
    for i in 0..plans {
        let mut link = NetLink::new(BandwidthTrace::constant(16.0));
        let mut p = DecodePool::new(14, h20_table());
        let mut est = BandwidthEstimator::new(0.5);
        std::hint::black_box(plan_fetch(
            i as f64, 100_000, raw, &profile, &cfg, &mut link, &mut p, &mut est,
        ));
    }
    let dt = t0.elapsed().as_secs_f64();
    rows.push(vec![
        "plan_fetch (10 chunks, fresh state)".into(),
        format!(
            "{:.0}K plans/s ({:.0}K chunk-events/s)",
            plans as f64 / dt / 1e3,
            plans as f64 * 10.0 / dt / 1e3
        ),
    ]);

    // allocator churn
    let mut alloc = BlockAllocator::new(4096, 256);
    let t0 = std::time::Instant::now();
    let rounds = 200_000;
    for _ in 0..rounds {
        let b = alloc.alloc(8).unwrap();
        alloc.release_all(&b);
    }
    let dt = t0.elapsed().as_secs_f64();
    rows.push(vec![
        "paged alloc/release (8 blocks)".into(),
        format!("{:.1}M ops/s", rounds as f64 / dt / 1e6),
    ]);

    // full engine sim throughput (requests simulated per second)
    let trace = generate(&TraceConfig { n_requests: 256, rate: 1.0, ..Default::default() });
    let t0 = std::time::Instant::now();
    let mut eng = EngineSim::new(
        perf.clone(),
        SystemProfile::kvfetcher(),
        EngineConfig::default(),
        BandwidthTrace::constant(16.0),
    );
    let rec = eng.run(&trace);
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(rec.records.len(), trace.len());
    rows.push(vec![
        "EngineSim end-to-end (256 reqs)".into(),
        format!("{:.0} simulated reqs/s", trace.len() as f64 / dt),
    ]);

    println!("{}", markdown(&["hot path", "throughput"], &rows));
    println!("target (DESIGN.md §7): fetch-path event loop >= 100k events/s");
}
