//! Fig. 19 — TTFT & TPOT of non-reuse requests on a real-world-style
//! arrival trace (0.2 req/s, 40K-token reuse threshold), comparing
//! KVFetcher / CacheGen / Full prefill full-engine simulations.

use kvfetcher::baselines::SystemProfile;
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::engine::{EngineConfig, EngineSim, ExecMode};
use kvfetcher::net::BandwidthTrace;
use kvfetcher::scheduler::SchedulerConfig;
use kvfetcher::trace::{generate, TraceConfig};
use kvfetcher::util::table::{fmt_secs, markdown};

fn main() {
    println!("# Fig. 19 — non-reuse TTFT and overall TPOT under a serving trace\n");
    let dev = DeviceSpec::h20();
    let perf = PerfModel::new(dev.clone(), ModelSpec::yi_34b());
    // every >=40K-context request reuses (the paper's setup: "prefill
    // requests with <40K context tokens and reuse remote KV for
    // >40K-token requests"); 8 Gbps keeps fetches long enough that a
    // fetching-agnostic scheduler visibly blocks the small requests.
    let trace = generate(&TraceConfig {
        seed: 19,
        n_requests: 48,
        rate: 0.2, // the paper's trace arrival rate
        ctx_min: 4_000,
        ctx_max: 160_000,
        reuse_frac: 1.0,
        reuse_threshold: 40_000, // the paper's threshold
        reuse_share: 0.99,       // suffix = the new query (~1K tokens)
        ..Default::default()
    });
    let bw = BandwidthTrace::constant(8.0);
    println!(
        "trace: {} requests @0.2 req/s | {} fetch-eligible | Yi-34B on 2x H20 | 8 Gbps\n",
        trace.len(),
        trace.iter().filter(|r| r.is_fetch()).count()
    );

    let mut rows = Vec::new();
    let mut results = std::collections::BTreeMap::new();
    for profile in [
        SystemProfile::kvfetcher(),
        SystemProfile::cachegen(&dev),
        SystemProfile::full_prefill(),
    ] {
        let cfg = EngineConfig {
            sched: SchedulerConfig {
                fetching_aware: profile.fetching_aware,
                ..Default::default()
            },
            layerwise_pipeline: profile.fetching_aware,
            ..Default::default()
        };
        let mut eng = EngineSim::new(perf.clone(), profile.clone(), cfg, bw.clone());
        let rec = eng.run(&trace);
        let non = rec.ttft_summary(Some(false));
        let tpot = rec.tpot_summary(None);
        results.insert(profile.name, (non.mean, tpot.mean));
        rows.push(vec![
            profile.name.to_string(),
            fmt_secs(non.mean),
            fmt_secs(non.p90),
            fmt_secs(tpot.mean),
        ]);
    }
    println!(
        "{}",
        markdown(&["system", "non-reuse TTFT", "non-reuse p90", "TPOT (all)"], &rows)
    );

    let (kvf_ttft, kvf_tpot) = results["KVFetcher"];
    let (cg_ttft, cg_tpot) = results["CacheGen"];
    let (fp_ttft, fp_tpot) = results["FullPrefill"];
    println!(
        "non-reuse TTFT reduction: {:.1}% vs CacheGen (paper 77.1%), {:.1}% vs FullPrefill \
         (paper 98%)",
        (1.0 - kvf_ttft / cg_ttft) * 100.0,
        (1.0 - kvf_ttft / fp_ttft) * 100.0
    );
    println!(
        "TPOT reduction: {:.1}% vs CacheGen (paper 35.4%), {:.1}% vs FullPrefill (paper 40%)",
        (1.0 - kvf_tpot / cg_tpot) * 100.0,
        (1.0 - kvf_tpot / fp_tpot) * 100.0
    );
    assert!(kvf_ttft < cg_ttft, "KVFetcher must protect non-reuse TTFT");
    assert!(kvf_ttft < fp_ttft);

    // ExecMode cross-check: replaying the same trace through the
    // threaded pipelined executor must reproduce the analytic engine's
    // non-reuse TTFT within 5%.
    let profile = SystemProfile::kvfetcher();
    let cfg = EngineConfig {
        sched: SchedulerConfig { fetching_aware: profile.fetching_aware, ..Default::default() },
        layerwise_pipeline: profile.fetching_aware,
        exec: ExecMode::Pipelined,
        ..Default::default()
    };
    let mut eng = EngineSim::new(perf.clone(), profile, cfg, bw.clone());
    let pipelined = eng.run(&trace).ttft_summary(Some(false)).mean;
    println!(
        "pipelined-executor replay: non-reuse TTFT {} (analytic {})",
        fmt_secs(pipelined),
        fmt_secs(kvf_ttft)
    );
    assert!(
        (pipelined - kvf_ttft).abs() <= 0.05 * kvf_ttft,
        "pipelined {pipelined:.4}s deviates >5% from analytic {kvf_ttft:.4}s"
    );
}
