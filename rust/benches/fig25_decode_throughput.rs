//! Fig. 25 — KV decode throughput (tokens/s) per platform, NVDEC pool
//! vs CacheGen's CUDA kernel, using the paper's testbed GPU counts
//! (Yi-34B: 4x L20, 2x H20, 2x A100).
//!
//! Known deviation (see EXPERIMENTS.md): the paper's Tables 1-3
//! per-chunk latencies imply a *higher* steady-state NVDEC throughput
//! than its Fig. 25 reports; we reproduce the table-implied numbers and
//! the CacheGen comparison, and state the paper values alongside.

use kvfetcher::asic::DecodePool;
use kvfetcher::baselines::cachegen_tokens_per_sec;
use kvfetcher::cluster::{DeviceSpec, ModelSpec};
use kvfetcher::util::table::markdown;

fn main() {
    println!("# Fig. 25 — decode throughput by platform (Yi-34B)\n");
    let model = ModelSpec::yi_34b();
    let devices = [DeviceSpec::l20(), DeviceSpec::h20(), DeviceSpec::a100()];
    let paper_ours = [27_000.0, 67_000.0, 47_000.0];
    let chunk_tokens = 10_000usize;
    let n_chunks = 64;

    let mut rows = Vec::new();
    for (dev, paper) in devices.iter().zip(paper_ours) {
        let n_gpus = model.gpus_on(dev);
        let units = dev.nvdecs * n_gpus;
        let mut pool = DecodePool::new(units, dev.decode_table());
        // saturate the pool: decode n_chunks back-to-back at 1080p
        let mut last_end = 0.0f64;
        for _ in 0..n_chunks {
            let job = pool.decode(0.0, 3, 1.0);
            last_end = last_end.max(job.end);
        }
        let ours_tps = (n_chunks * chunk_tokens) as f64 / last_end;
        // paper used 2-GPU cachegen numbers
        let cg_tps = cachegen_tokens_per_sec(dev) * n_gpus as f64 / 2.0;
        rows.push(vec![
            format!("{}x {}", n_gpus, dev.name),
            format!("{units}"),
            format!("{:.0}K", ours_tps / 1e3),
            format!("{:.0}K", paper / 1e3),
            format!("{:.0}K", cg_tps / 1e3),
            format!("{:.2}", ours_tps / cg_tps),
        ]);
    }
    println!(
        "{}",
        markdown(
            &[
                "platform",
                "NVDECs",
                "ours (sim, table-implied)",
                "ours (paper)",
                "CacheGen CUDA",
                "ratio",
            ],
            &rows
        )
    );
    println!(
        "paper ratios ours/CacheGen: L20 0.3x, H20 1.34x, A100 0.88x. Our pool is\n\
         bounded by unit count x per-chunk table latency; the paper's Fig. 25 is\n\
         lower than its own tables imply — we report both."
    );
}
