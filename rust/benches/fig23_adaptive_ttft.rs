//! Fig. 23 (with Fig. 17's bandwidth pattern) — TTFT breakdown across
//! baselines and the adaptive-resolution ablation under dynamic
//! bandwidth. Paper: adaptive resolution saves ~20% vs fixed 1080p;
//! per-chunk decode latency stays under ~400ms; reuse prefill under 50ms
//! of *incremental* compute per chunk.

use kvfetcher::asic::{h20_table, DecodePool};
use kvfetcher::baselines::SystemProfile;
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::engine::ExecMode;
use kvfetcher::fetcher::{FetchConfig, FetchRequest, Fetcher};
use kvfetcher::net::BandwidthTrace;
use kvfetcher::util::table::{fmt_secs, markdown};

fn main() {
    println!("# Fig. 23 — TTFT breakdown under the Fig. 17 bandwidth pattern\n");
    let dev = DeviceSpec::h20();
    let perf = PerfModel::new(dev.clone(), ModelSpec::yi_34b());
    let tokens = 100_000usize;
    let raw = perf.kv_bytes(tokens);
    let suffix_prefill = perf.prefill_time(2_000, tokens);

    let mut rows = Vec::new();
    let mut totals = std::collections::BTreeMap::new();
    let variants: [(&str, SystemProfile, bool); 4] = [
        ("KVFetcher (adaptive)", SystemProfile::kvfetcher(), true),
        ("KVFetcher (fixed 1080p)", SystemProfile::kvfetcher(), false),
        ("CacheGen", SystemProfile::cachegen(&dev), false),
        ("RawReuse", SystemProfile::raw_reuse(), false),
    ];
    for (name, profile, adaptive) in variants {
        let mut fetcher = Fetcher::builder()
            .profile(profile)
            .fetch_config(FetchConfig { adaptive, default_bw_gbps: 6.0, ..Default::default() })
            .bandwidth(BandwidthTrace::fig17())
            .decode_pool(DecodePool::new(dev.nvdecs * perf.n_gpus, h20_table()))
            .build();
        let plan = fetcher.run(&FetchRequest::new(tokens, raw)).expect("analytic fetch").plan;
        let total = plan.done_at + suffix_prefill;
        totals.insert(name, total);
        let max_chunk_dec = plan
            .chunks
            .iter()
            .map(|c| c.dec_end - c.dec_start)
            .fold(0.0f64, f64::max);
        rows.push(vec![
            name.to_string(),
            fmt_secs(plan.breakdown.transmission),
            fmt_secs(plan.breakdown.decode),
            fmt_secs(plan.breakdown.restore),
            fmt_secs(suffix_prefill),
            fmt_secs(total),
            fmt_secs(max_chunk_dec),
        ]);
    }
    println!(
        "{}",
        markdown(
            &["system", "trans", "decode tail", "restore", "prefill", "TTFT", "max chunk decode"],
            &rows
        )
    );
    let saving = (totals["KVFetcher (fixed 1080p)"] - totals["KVFetcher (adaptive)"])
        / totals["KVFetcher (fixed 1080p)"]
        * 100.0;
    println!("adaptive saving vs fixed: {saving:.1}% (paper: ~20%)");
    assert!(
        totals["KVFetcher (adaptive)"] <= totals["KVFetcher (fixed 1080p)"] + 1e-9,
        "adaptive must not lose to fixed"
    );
    assert!(totals["KVFetcher (adaptive)"] < totals["CacheGen"]);

    // ExecMode cross-check under the dynamic-bandwidth pattern: the
    // threaded executor picks the same per-chunk resolutions and lands
    // within 5% of the analytic TTFT.
    let mut fetcher = Fetcher::builder()
        .profile(SystemProfile::kvfetcher())
        .fetch_config(FetchConfig { adaptive: true, default_bw_gbps: 6.0, ..Default::default() })
        .bandwidth(BandwidthTrace::fig17())
        .decode_pool(DecodePool::new(dev.nvdecs * perf.n_gpus, h20_table()))
        .build();
    let req = FetchRequest::new(tokens, raw).exec(ExecMode::Pipelined);
    let out = fetcher.run(&req).expect("pipelined fetch");
    let pipelined_total = out.plan.done_at + suffix_prefill;
    let analytic_total = totals["KVFetcher (adaptive)"];
    println!(
        "pipelined executor under Fig. 17 bandwidth: TTFT {} (analytic {})",
        fmt_secs(pipelined_total),
        fmt_secs(analytic_total)
    );
    assert!(
        (pipelined_total - analytic_total).abs() <= 0.05 * analytic_total,
        "pipelined {pipelined_total:.4}s deviates >5% from analytic {analytic_total:.4}s"
    );
}
