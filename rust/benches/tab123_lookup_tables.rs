//! Tables 1-3 (Appx. A.2) — the per-device decode-latency lookup tables
//! that drive Alg. 1, printed verbatim from the `asic` module and
//! validated for the structural properties the adapter relies on.

use kvfetcher::asic::{a100_table, h20_table, l20_table, LookupTable, TABLE_RESOLUTIONS};
use kvfetcher::util::table::markdown;

fn print_table(name: &str, t: &LookupTable, units: usize) {
    println!("## {name} ({units} NVDECs)");
    let mut rows = Vec::new();
    for (c, lat) in t.latency.iter().enumerate() {
        rows.push(
            std::iter::once((c + 1).to_string())
                .chain(lat.iter().map(|l| format!("{l:.3}")))
                .collect(),
        );
    }
    rows.push(
        std::iter::once("penalty".to_string())
            .chain(t.penalty.iter().map(|p| format!("{p:.2}")))
            .collect(),
    );
    rows.push(
        std::iter::once("size(MB)".to_string())
            .chain(t.size_mb.iter().map(|s| format!("{s:.0}")))
            .collect(),
    );
    let headers: Vec<&str> = std::iter::once("conc").chain(TABLE_RESOLUTIONS).collect();
    println!("{}", markdown(&headers, &rows));
}

fn validate(name: &str, t: &LookupTable) {
    // latency non-decreasing in concurrency for every resolution
    for r in 0..4 {
        for c in 1..t.latency.len() {
            assert!(
                t.latency[c][r] >= t.latency[c - 1][r] - 1e-9,
                "{name}: latency must not drop with concurrency (res {r}, conc {c})"
            );
        }
    }
    // higher resolution decodes no slower at fixed concurrency — the
    // paper's own Table 1 has one 10ms wobble (conc 3: 240p 0.29 vs
    // 480p 0.30), so allow measurement-noise tolerance
    for row in &t.latency {
        for r in 1..4 {
            assert!(row[r] <= row[r - 1] + 0.015, "{name}: resolution monotonicity");
        }
    }
    // 1080p needs no switch penalty; sizes grow with resolution
    assert_eq!(t.penalty[3], 0.0, "{name}");
    for r in 1..4 {
        assert!(t.size_mb[r] > t.size_mb[r - 1], "{name}: sizes grow with resolution");
    }
}

fn main() {
    println!("# Tables 1-3 — NVDEC decode-latency lookup tables\n");
    let tables = [
        ("Table 1: H20", h20_table(), 7),
        ("Table 2: L20", l20_table(), 3),
        ("Table 3: A100", a100_table(), 5),
    ];
    for (name, t, units) in &tables {
        print_table(name, t, *units);
        validate(name, t);
        assert_eq!(t.max_concurrency(), *units, "{name}: one row per concurrent chunk");
    }
    println!(
        "all structural properties hold: latency rises with pool load, falls with\n\
         resolution; only sub-1080p switches pay a penalty; sizes grow with resolution."
    );
}
