//! §Perf — codec hot-path throughput: rANS encode/decode, full video
//! encode/decode, and end-to-end chunk restore, in MB/s. The L3 target
//! (DESIGN.md §7): encode >= 200 MB/s, decode >= 300 MB/s per core so
//! the simulated NVDEC latency — not host CPU — is always the modelled
//! cost in the examples.
//!
//! Run: `cargo bench --bench perf_codec -- [--quick] [--out file]`
//! Writes the run as `BENCH_perf_codec.json` (schema version 1,
//! validated by `python/tools/check_bench_schema.py` in the CI
//! `bench-trajectory` job); `--quick` shrinks inputs and reps for CI.

use std::collections::BTreeMap;

use kvfetcher::codec::{decode_video, encode_video, rans, CodecConfig};
use kvfetcher::engine::real::best_intra;
use kvfetcher::layout::{decode_chunk, encode_chunk, Resolution};
use kvfetcher::quant::quantize;
use kvfetcher::tensor::KvCache;
use kvfetcher::util::json::Json;
use kvfetcher::util::proptest::gen_bytes;
use kvfetcher::util::table::markdown;
use kvfetcher::util::Prng;

fn parse_flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

/// The `BENCH_*.json` perf-trajectory point of a micro-bench run
/// (schema version 1, `points` variant — validated by
/// `python/tools/check_bench_schema.py`).
fn bench_json(bench: &str, points: &[(String, f64, &'static str)]) -> Json {
    let arr = points
        .iter()
        .map(|(name, value, unit)| {
            let mut p = BTreeMap::new();
            p.insert("name".into(), Json::Str(name.clone()));
            p.insert("value".into(), Json::Num(*value));
            p.insert("unit".into(), Json::Str((*unit).into()));
            Json::Obj(p)
        })
        .collect();
    let mut o = BTreeMap::new();
    o.insert("bench".into(), Json::Str(bench.into()));
    o.insert("schema_version".into(), Json::Num(1.0));
    o.insert("points".into(), Json::Arr(arr));
    Json::Obj(o)
}

fn time<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let reps = if quick { 1 } else { 3 };
    println!("# perf_codec — host codec throughput\n");
    let mut rng = Prng::new(123);
    let mut rows = Vec::new();
    let mut points: Vec<(String, f64, &'static str)> = Vec::new();

    // rANS on residual-like (peaked) data
    let peaked = gen_bytes(&mut rng, if quick { 2 << 20 } else { 8 << 20 }, true);
    let enc = rans::encode(&peaked);
    let t_enc = time(reps, || {
        std::hint::black_box(rans::encode(&peaked));
    });
    let t_dec = time(reps, || {
        std::hint::black_box(rans::decode(&enc).unwrap());
    });
    let mb = (peaked.len() >> 20) as f64;
    rows.push(vec![format!("rANS encode (peaked {mb:.0}MB)"), format!("{:.0} MB/s", mb / t_enc)]);
    rows.push(vec![format!("rANS decode (peaked {mb:.0}MB)"), format!("{:.0} MB/s", mb / t_dec)]);
    points.push(("rans_encode".into(), mb / t_enc, "MB/s"));
    points.push(("rans_decode".into(), mb / t_dec, "MB/s"));

    // full video pipeline on a 1024-token chunk (8 planes, 8x32)
    let kv = KvCache::synthetic(&mut rng, 1024, 8, 8, 32, 0.97);
    let q = quantize(&kv);
    let res = Resolution { name: "640p", w: 256, h: 128 };
    let intra = best_intra(&q, res);
    let raw_mb = q.data.len() as f64 / (1 << 20) as f64;
    let groups = encode_chunk(&q, res, intra, &CodecConfig::lossless()).unwrap();
    let t_venc = time(reps, || {
        std::hint::black_box(encode_chunk(&q, res, intra, &CodecConfig::lossless()).unwrap());
    });
    let t_vdec = time(reps, || {
        std::hint::black_box(decode_chunk(&groups, q.scales.clone()).unwrap());
    });
    rows.push(vec![
        format!("video encode ({raw_mb:.0}MB chunk)"),
        format!("{:.0} MB/s", raw_mb / t_venc),
    ]);
    rows.push(vec![
        format!("video decode+restore ({raw_mb:.0}MB chunk)"),
        format!("{:.0} MB/s", raw_mb / t_vdec),
    ]);
    points.push(("chunk_encode".into(), raw_mb / t_venc, "MB/s"));
    points.push(("chunk_decode_restore".into(), raw_mb / t_vdec, "MB/s"));

    // single-video paths (frames only, no layout) for profiling deltas
    let frames = groups[0].layout.build_frames(&q);
    let (bytes, _) = encode_video(&frames, &CodecConfig::lossless(), &[]);
    let t_e1 = time(reps, || {
        std::hint::black_box(encode_video(&frames, &CodecConfig::lossless(), &[]));
    });
    let t_d1 = time(reps, || {
        std::hint::black_box(decode_video(&bytes).unwrap());
    });
    let fmb = frames.iter().map(|f| f.byte_len()).sum::<usize>() as f64 / (1 << 20) as f64;
    rows.push(vec![format!("encode_video ({fmb:.1}MB frames)"), format!("{:.0} MB/s", fmb / t_e1)]);
    rows.push(vec![format!("decode_video ({fmb:.1}MB frames)"), format!("{:.0} MB/s", fmb / t_d1)]);
    points.push(("encode_video".into(), fmb / t_e1, "MB/s"));
    points.push(("decode_video".into(), fmb / t_d1, "MB/s"));

    println!("{}", markdown(&["path", "throughput"], &rows));
    println!("targets (DESIGN.md §7): encode >= 200 MB/s, decode >= 300 MB/s");

    let out = parse_flag(&args, "--out").unwrap_or_else(|| "BENCH_perf_codec.json".into());
    let json = bench_json("perf_codec", &points);
    if let Err(e) = std::fs::write(&out, json.to_string() + "\n") {
        eprintln!("cannot write {out}: {e}");
        std::process::exit(1);
    }
    println!("wrote {out}");
}
