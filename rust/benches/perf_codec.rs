//! §Perf — codec hot-path throughput: rANS encode/decode, full video
//! encode/decode, and end-to-end chunk restore, in MB/s. The L3 target
//! (DESIGN.md §7): encode >= 200 MB/s, decode >= 300 MB/s per core so
//! the simulated NVDEC latency — not host CPU — is always the modelled
//! cost in the examples.

use kvfetcher::codec::{decode_video, encode_video, rans, CodecConfig};
use kvfetcher::engine::real::best_intra;
use kvfetcher::layout::{decode_chunk, encode_chunk, Resolution};
use kvfetcher::quant::quantize;
use kvfetcher::tensor::KvCache;
use kvfetcher::util::proptest::gen_bytes;
use kvfetcher::util::table::markdown;
use kvfetcher::util::Prng;

fn time<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    println!("# perf_codec — host codec throughput\n");
    let mut rng = Prng::new(123);
    let mut rows = Vec::new();

    // rANS on residual-like (peaked) data, 8 MB
    let peaked = gen_bytes(&mut rng, 8 << 20, true);
    let enc = rans::encode(&peaked);
    let t_enc = time(3, || {
        std::hint::black_box(rans::encode(&peaked));
    });
    let t_dec = time(3, || {
        std::hint::black_box(rans::decode(&enc).unwrap());
    });
    let mb = (peaked.len() >> 20) as f64;
    rows.push(vec!["rANS encode (peaked 8MB)".into(), format!("{:.0} MB/s", mb / t_enc)]);
    rows.push(vec!["rANS decode (peaked 8MB)".into(), format!("{:.0} MB/s", mb / t_dec)]);

    // full video pipeline on a 1024-token chunk (8 planes, 8x32)
    let kv = KvCache::synthetic(&mut rng, 1024, 8, 8, 32, 0.97);
    let q = quantize(&kv);
    let res = Resolution { name: "640p", w: 256, h: 128 };
    let intra = best_intra(&q, res);
    let raw_mb = q.data.len() as f64 / (1 << 20) as f64;
    let groups = encode_chunk(&q, res, intra, &CodecConfig::lossless()).unwrap();
    let t_venc = time(3, || {
        std::hint::black_box(encode_chunk(&q, res, intra, &CodecConfig::lossless()).unwrap());
    });
    let t_vdec = time(3, || {
        std::hint::black_box(decode_chunk(&groups, q.scales.clone()).unwrap());
    });
    rows.push(vec![
        format!("video encode ({raw_mb:.0}MB chunk)"),
        format!("{:.0} MB/s", raw_mb / t_venc),
    ]);
    rows.push(vec![
        format!("video decode+restore ({raw_mb:.0}MB chunk)"),
        format!("{:.0} MB/s", raw_mb / t_vdec),
    ]);

    // single-video paths (frames only, no layout) for profiling deltas
    let frames = groups[0].layout.build_frames(&q);
    let (bytes, _) = encode_video(&frames, &CodecConfig::lossless(), &[]);
    let t_e1 = time(3, || {
        std::hint::black_box(encode_video(&frames, &CodecConfig::lossless(), &[]));
    });
    let t_d1 = time(3, || {
        std::hint::black_box(decode_video(&bytes).unwrap());
    });
    let fmb = frames.iter().map(|f| f.byte_len()).sum::<usize>() as f64 / (1 << 20) as f64;
    rows.push(vec![format!("encode_video ({fmb:.1}MB frames)"), format!("{:.0} MB/s", fmb / t_e1)]);
    rows.push(vec![format!("decode_video ({fmb:.1}MB frames)"), format!("{:.0} MB/s", fmb / t_d1)]);

    println!("{}", markdown(&["path", "throughput"], &rows));
    println!("targets (DESIGN.md §7): encode >= 200 MB/s, decode >= 300 MB/s");
}
