//! Fig. 11 / Fig. 26 — image similarity (SSIM & PSNR) of consecutive
//! slices of the KV cache along token / head / layer dimensions.
//! Measured on the REAL tiny model's KV when artifacts exist, plus the
//! synthetic generator for the paper-scale shape.
//!
//! Paper result: token slicing is by far the most similar (SSIM ~0.87),
//! then head, then layer — the foundation of the inter-frame layout.

use kvfetcher::runtime::{kv_to_cache, Runtime};
use kvfetcher::tensor::{psnr, ssim, KvCache};
use kvfetcher::util::table::markdown;
use kvfetcher::util::Prng;

fn mean_similarity(imgs: &[(usize, usize, Vec<u8>)]) -> (f64, f64) {
    let (mut s_acc, mut p_acc, mut n) = (0.0, 0.0, 0);
    for w in imgs.windows(2) {
        s_acc += ssim(&w[0].2, &w[1].2, w[0].0, w[0].1);
        let p = psnr(&w[0].2, &w[1].2);
        p_acc += if p.is_finite() { p } else { 96.0 }; // cap identical frames
        n += 1;
    }
    (s_acc / n as f64, p_acc / n as f64)
}

fn report(label: &str, kv: &KvCache) {
    let dims = [("token", 0usize), ("layer", 1), ("head", 2)];
    let mut rows = Vec::new();
    let mut sims = Vec::new();
    for (name, d) in dims {
        let (s, p) = mean_similarity(&kv.slice_images(d));
        sims.push((name, s));
        rows.push(vec![name.to_string(), format!("{s:.3}"), format!("{p:.1} dB")]);
    }
    println!("## {label}");
    println!("{}", markdown(&["slicing dim", "SSIM", "PSNR"], &rows));
    let tok = sims.iter().find(|(n, _)| *n == "token").unwrap().1;
    assert!(
        sims.iter().all(|&(n, s)| n == "token" || s <= tok + 1e-9),
        "token slicing must maximize similarity: {sims:?}"
    );
}

fn main() {
    println!("# Fig. 11 / Fig. 26 — KV slice similarity by dimension\n");

    // real model KV (random-token prompt)
    if let Ok(rt) = Runtime::load("artifacts") {
        let mut rng = Prng::new(5);
        let tokens: Vec<i32> =
            (0..rt.cfg.prefix_len).map(|_| rng.below(rt.cfg.vocab as u64) as i32).collect();
        let (_, kv_flat) = rt.prefill_prefix(&tokens).expect("prefill");
        let cache = kv_to_cache(&rt.cfg, rt.cfg.prefix_len, &kv_flat);
        report("real tiny-model KV (PJRT, 128 tokens)", &cache);
    } else {
        println!("(artifacts missing; skipping the real-model measurement)\n");
    }

    // synthetic KV at a paper-like shape (32 heads x 128 dim slice)
    let mut rng = Prng::new(6);
    let kv = KvCache::synthetic(&mut rng, 96, 6, 16, 64, 0.95);
    report("synthetic KV (AR(0.95) tokens, 6 planes, 16x64)", &kv);

    println!("paper values for reference: SSIM token 0.87 > head 0.62 > layer 0.23");
}
