//! Fig. 12 — tensor placement & resolution effects.
//!   (top)    placing 4 consecutive token tensors on 4 consecutive
//!            frames compresses better than stitching them into one
//!            frame (paper: 1.6x gain);
//!   (bottom) video size grows with resolution while NVDEC decode
//!            latency shrinks (the tension Alg. 1 balances).

use kvfetcher::asic::{h20_table, TABLE_RESOLUTIONS};
use kvfetcher::codec::{encode_video, CodecConfig, Frame};
use kvfetcher::fetcher::RES_SIZE_FACTOR;
use kvfetcher::layout::{encode_chunk, IntraLayout, Resolution};
use kvfetcher::quant::quantize;
use kvfetcher::tensor::KvCache;
use kvfetcher::util::table::markdown;
use kvfetcher::util::Prng;

fn main() {
    println!("# Fig. 12 — placement (top) and resolution (bottom)\n");
    let mut rng = Prng::new(8);
    let kv = KvCache::synthetic(&mut rng, 256, 3, 8, 32, 0.97);
    let q = quantize(&kv);
    let intra = IntraLayout { hr: 2, hc: 4, dr: 8, dc: 4 }; // tile 16x16

    // (top) four tensors: 4 frames vs one stitched frame
    let chans = q.per_plane_channels();
    let tile = |t: usize| -> Vec<[u8; 256]> {
        // 3 planes x 16x16 tile of token t
        let mut planes = vec![[0u8; 256]; 3];
        for p in 0..3 {
            for h in 0..8 {
                for d in 0..32 {
                    let (r, c) = intra.pixel_of(h, d);
                    planes[p][r * 16 + c] = q.data[(t * q.planes + p) * chans + h * 32 + d];
                }
            }
        }
        planes
    };
    // multi-frame: 4 frames of 16x16
    let mut multi = Vec::new();
    for t in 0..4 {
        let planes = tile(t);
        let mut f = Frame::new(16, 16);
        for p in 0..3 {
            f.planes[p].copy_from_slice(&planes[p]);
        }
        multi.push(f);
    }
    let (multi_bytes, _) = encode_video(&multi, &CodecConfig::lossless(), &[]);
    // single frame: 4 tiles stitched horizontally (64x16)
    let mut single = Frame::new(64, 16);
    for t in 0..4 {
        let planes = tile(t);
        for p in 0..3 {
            for r in 0..16 {
                for c in 0..16 {
                    single.set(p, t * 16 + c, r, planes[p][r * 16 + c]);
                }
            }
        }
    }
    let (single_bytes, _) = encode_video(&[single], &CodecConfig::lossless(), &[]);
    println!("## (top) 4 consecutive token tensors");
    let gain = single_bytes.len() as f64 / multi_bytes.len() as f64;
    println!(
        "{}",
        markdown(
            &["placement", "encoded bytes"],
            &[
                vec!["4 consecutive frames".into(), multi_bytes.len().to_string()],
                vec!["stitched in one frame".into(), single_bytes.len().to_string()],
            ],
        )
    );
    println!("multi-frame gain: {gain:.2}x (paper: ~1.6x)\n");
    assert!(gain > 1.0, "multi-frame placement must win");

    // (bottom) resolution sweep: real encoded size + table decode latency
    println!("## (bottom) resolution vs size and decode latency");
    let table = h20_table();
    let resolutions = [
        Resolution { name: "240p", w: 48, h: 32 },
        Resolution { name: "480p", w: 96, h: 48 },
        Resolution { name: "640p", w: 128, h: 64 },
        Resolution { name: "1080p", w: 192, h: 112 },
    ];
    let mut rows = Vec::new();
    let mut sizes = Vec::new();
    for (i, res) in resolutions.iter().enumerate() {
        let groups = encode_chunk(&q, *res, intra, &CodecConfig::lossless()).unwrap();
        let bytes: usize = groups.iter().map(|g| g.bytes.len()).sum();
        sizes.push(bytes);
        rows.push(vec![
            res.name.to_string(),
            format!("{}", groups[0].layout.n_frames),
            bytes.to_string(),
            format!("{:.0} ms", table.latency_at(i, 1) * 1e3),
            format!("{:.2}", RES_SIZE_FACTOR[i]),
        ]);
    }
    println!(
        "{}",
        markdown(
            &[
                "resolution",
                "frames",
                "encoded bytes (real)",
                "decode @conc1 (table)",
                "paper size factor",
            ],
            &rows
        )
    );
    assert_eq!(TABLE_RESOLUTIONS.len(), 4);
    println!(
        "shape check: measured size grows with resolution ({} -> {}) while the\n\
         ASIC decode latency falls (0.21s -> 0.19s at concurrency 1) — the\n\
         transmission/decoding tension of observation (iii).",
        sizes[0],
        sizes[3]
    );
}
