//! Fig. 22 — compression-ratio breakdown per model: quantization,
//! + inter-frame layout (token-sliced multi-frame video), + intra-frame
//! layout (best tiling). Measured with the real codec on synthetic KV
//! shaped like each model (GQA-aware).

use kvfetcher::baselines::calibrate_ratios;
use kvfetcher::cluster::ModelSpec;
use kvfetcher::util::table::markdown;

fn main() {
    println!("# Fig. 22 — compression-ratio breakdown by stage (real codec)\n");
    // (model, kv-head count, head_dim scaled down 4x to keep the bench
    // fast; ratios depend on shape, not absolute dim)
    let models = [ModelSpec::lwm_7b(), ModelSpec::yi_34b(), ModelSpec::llama3_70b()];
    let mut rows = Vec::new();
    for m in &models {
        let heads = m.kv_heads.min(16);
        let dim = 32;
        let r = calibrate_ratios(22, 192, 6, heads, dim, 0.98);
        rows.push(vec![
            format!("{} ({}kv x{})", m.name, heads, dim),
            format!("{:.2}x", r.quant_only),
            format!("{:.2}x", r.kvfetcher_inter_only),
            format!("{:.2}x", r.kvfetcher_full),
            format!(
                "{:.0}%",
                (r.kvfetcher_full / r.kvfetcher_inter_only - 1.0) * 100.0
            ),
        ]);
        assert!(r.kvfetcher_inter_only >= r.quant_only, "{}: inter must add gain", m.name);
        assert!(r.kvfetcher_full >= r.kvfetcher_inter_only * 0.999);
    }
    println!(
        "{}",
        markdown(
            &["model", "quant", "+inter-frame", "+intra-frame", "intra uplift"],
            &rows
        )
    );
    println!(
        "paper: quant ~2x; inter-frame adds 2.2x on top; intra-frame lifts the\n\
         total to 2.96x over quant (11.9x overall); the GQA models (fewest KV\n\
         heads) benefit relatively most from the intra stage. Our absolute video\n\
         gain is smaller (order-0 rANS vs CABAC) but the stage ordering and the\n\
         GQA trend reproduce."
    );
}
