//! Fig. 3 — "winning areas" of full prefill / raw KV reuse / compressed
//! KV reuse across bandwidth x context length. Reproduces the paper's
//! claim that KVFetcher widens the compressed-reuse winning area far
//! beyond CacheGen's dashed box.

use kvfetcher::baselines::{SystemKind, SystemProfile};
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::engine::ExecMode;
use kvfetcher::fetcher::Fetcher;
use kvfetcher::net::BandwidthTrace;

const BANDWIDTHS: [f64; 8] = [1.0, 2.0, 4.0, 8.0, 16.0, 40.0, 100.0, 200.0];
const CONTEXTS: [usize; 6] = [5_000, 20_000, 50_000, 100_000, 150_000, 200_000];

fn ttft(perf: &PerfModel, p: &SystemProfile, bw: f64, ctx: usize) -> f64 {
    let reusable =
        if p.kind == SystemKind::FullPrefill { 0 } else { (ctx as f64 * 0.95) as usize };
    Fetcher::builder()
        .profile(p.clone())
        .bandwidth(BandwidthTrace::constant(bw))
        .for_perf(perf)
        .build()
        .ttft(perf, ctx, reusable, ExecMode::Analytic)
        .total()
}

fn grid(perf: &PerfModel, dev: &DeviceSpec, include_kvf: bool) {
    let mut systems = vec![
        ("F", SystemProfile::full_prefill()),
        ("R", SystemProfile::raw_reuse()),
        ("C", SystemProfile::cachegen(dev)),
    ];
    if include_kvf {
        systems.push(("K", SystemProfile::kvfetcher()));
    }
    print!("{:>9} |", "ctx\\bw");
    for bw in BANDWIDTHS {
        print!("{:>6} ", format!("{bw}G"));
    }
    println!();
    let mut k_cells = 0;
    let mut c_cells = 0;
    for ctx in CONTEXTS {
        print!("{:>9} |", format!("{}K", ctx / 1000));
        for bw in BANDWIDTHS {
            let winner = systems
                .iter()
                .map(|(tag, p)| (*tag, ttft(perf, p, bw, ctx)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap()
                .0;
            if winner == "K" {
                k_cells += 1;
            }
            if winner == "C" {
                c_cells += 1;
            }
            print!("{:>6} ", winner);
        }
        println!();
    }
    if include_kvf {
        println!(
            "\ncompressed-reuse winning cells: KVFetcher {k_cells}/{} vs CacheGen-only run below",
            BANDWIDTHS.len() * CONTEXTS.len()
        );
    } else {
        println!(
            "\ncompressed-reuse winning cells: CacheGen {c_cells}/{}",
            BANDWIDTHS.len() * CONTEXTS.len()
        );
    }
}

fn main() {
    let dev = DeviceSpec::h20();
    let model = ModelSpec::lwm_7b(); // the paper's Fig. 3 uses LWM-7B on H20
    let perf = PerfModel::new(dev.clone(), model.clone());
    println!("# Fig. 3 — winning areas ({} on {} x{})", model.name, dev.name, perf.n_gpus);
    println!("\n## with KVFetcher available (paper: right panel)");
    grid(&perf, &dev, true);
    println!("\n## compressed reuse = CacheGen only (paper: left panel, dashed box)");
    grid(&perf, &dev, false);
    println!(
        "\npaper shape check: KVFetcher extends the compressed-reuse area across\n\
         nearly the whole 1-40 Gbps band; CacheGen's area is much smaller."
    );
}
