//! Fig. 18 — TTFT of a fetch request vs context length, for every
//! (device, model) pair of the paper's testbed and all five systems,
//! at the paper's default 16 Gbps.

use kvfetcher::baselines::{SystemKind, SystemProfile};
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::engine::ExecMode;
use kvfetcher::fetcher::Fetcher;
use kvfetcher::metrics::TtftBreakdown;
use kvfetcher::net::BandwidthTrace;
use kvfetcher::util::table::{fmt_secs, markdown};

/// One isolated-request TTFT through the `Fetcher` facade.
fn ttft(
    perf: &PerfModel,
    profile: &SystemProfile,
    bw: &BandwidthTrace,
    ctx: usize,
    reusable: usize,
    exec: ExecMode,
) -> TtftBreakdown {
    Fetcher::builder()
        .profile(profile.clone())
        .bandwidth(bw.clone())
        .for_perf(perf)
        .build()
        .ttft(perf, ctx, reusable, exec)
}

fn main() {
    println!("# Fig. 18 — fetch-request TTFT across devices, models, contexts (16 Gbps)\n");
    let devices = [DeviceSpec::a100(), DeviceSpec::h20(), DeviceSpec::l20()];
    let models = [ModelSpec::lwm_7b(), ModelSpec::yi_34b(), ModelSpec::llama3_70b()];
    let bw = BandwidthTrace::constant(16.0);

    let mut speedups_vs_full = Vec::new();
    let mut speedups_vs_raw = Vec::new();
    let mut speedups_vs_cg = Vec::new();

    for dev in &devices {
        for model in &models {
            let perf = PerfModel::new(dev.clone(), model.clone());
            // context range scaled to each model's window (paper panels)
            let max_ctx = match model.name {
                "LWM-7B" => 200_000,
                "Yi-34B" => 160_000,
                _ => 120_000,
            };
            let contexts = [max_ctx / 8, max_ctx / 4, max_ctx / 2, max_ctx];
            println!("## {} x{} | {}", dev.name, perf.n_gpus, model.name);
            let systems = SystemProfile::all(dev);
            let mut rows = Vec::new();
            for ctx in contexts {
                let reusable = (ctx as f64 * 0.95) as usize;
                let mut cells = vec![format!("{}K", ctx / 1000)];
                let mut ttfts = std::collections::BTreeMap::new();
                for p in &systems {
                    let r = if p.kind == SystemKind::FullPrefill { 0 } else { reusable };
                    let t = ttft(&perf, p, &bw, ctx, r, ExecMode::Analytic).total();
                    ttfts.insert(p.name, t);
                    cells.push(fmt_secs(t));
                }
                speedups_vs_full.push(ttfts["FullPrefill"] / ttfts["KVFetcher"]);
                speedups_vs_raw.push(ttfts["RawReuse"] / ttfts["KVFetcher"]);
                speedups_vs_cg.push(ttfts["CacheGen"] / ttfts["KVFetcher"]);
                rows.push(cells);
            }
            let headers: Vec<&str> = std::iter::once("ctx")
                .chain(systems.iter().map(|p| p.name))
                .collect();
            println!("{}", markdown(&headers, &rows));
        }
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "average KVFetcher speedup: {:.2}x vs FullPrefill (paper 13.63x), {:.2}x vs RawReuse \
         (paper 3.51x), {:.2}x vs CacheGen (paper 1.52x)",
        avg(&speedups_vs_full),
        avg(&speedups_vs_raw),
        avg(&speedups_vs_cg)
    );
    assert!(avg(&speedups_vs_full) > 3.0);
    assert!(avg(&speedups_vs_raw) > 1.3);
    assert!(avg(&speedups_vs_cg) > 1.05);

    // ExecMode cross-check: the threaded pipelined executor must
    // reproduce the analytic model's TTFT within 5% on every grid cell.
    println!("\n## ExecMode cross-check (pipelined executor vs analytic model)");
    let ours = SystemProfile::kvfetcher();
    let mut worst = 0.0f64;
    for dev in &devices {
        for model in &models {
            let perf = PerfModel::new(dev.clone(), model.clone());
            let max_ctx = match model.name {
                "LWM-7B" => 200_000,
                "Yi-34B" => 160_000,
                _ => 120_000,
            };
            for ctx in [max_ctx / 4, max_ctx] {
                let reusable = (ctx as f64 * 0.95) as usize;
                let a = ttft(&perf, &ours, &bw, ctx, reusable, ExecMode::Analytic).total();
                let p = ttft(&perf, &ours, &bw, ctx, reusable, ExecMode::Pipelined).total();
                let rel = (p - a).abs() / a;
                worst = worst.max(rel);
                assert!(
                    rel <= 0.05,
                    "{} {} ctx={}: pipelined {:.4}s deviates {:.2}% from analytic {:.4}s",
                    dev.name,
                    model.name,
                    ctx,
                    p,
                    rel * 100.0,
                    a
                );
            }
        }
    }
    println!("pipelined executor matches analytic TTFT within 5% (worst {:.4}%)", worst * 100.0);
}
