//! Fig. 24 — GPU memory of concurrently decoding + restoring 7 video
//! chunks: frame-wise restoration vs chunk-wise vs CacheGen's CUDA
//! buffer. Paper: 7 concurrent chunks ~400MB peak; a single fetch needs
//! ~40MB decode + ~47MB restore; chunk-wise designs spike to 1.5-2GB.

use kvfetcher::baselines::SystemProfile;
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::fetcher::{restore_memory, FetchConfig};
use kvfetcher::util::table::{fmt_bytes, markdown};

fn main() {
    println!("# Fig. 24 — decompression memory footprint\n");
    let perf = PerfModel::new(DeviceSpec::h20(), ModelSpec::yi_34b());
    let raw_per_chunk = perf.kv_bytes(10_000); // one 10K-token chunk

    let ours = SystemProfile::kvfetcher();
    let cachegen = SystemProfile::cachegen(&DeviceSpec::h20());
    let fw = FetchConfig::default();
    let cw = FetchConfig { framewise_restore: false, ..Default::default() };

    let one_fw = restore_memory(&ours, &fw, raw_per_chunk);
    let one_cw = restore_memory(&ours, &cw, raw_per_chunk);
    let one_cg = restore_memory(&cachegen, &fw, raw_per_chunk);

    let rows = vec![
        vec!["KVFetcher frame-wise, 1 chunk".into(), fmt_bytes(one_fw)],
        vec!["KVFetcher frame-wise, 7 concurrent".into(), fmt_bytes(7 * one_fw)],
        vec!["chunk-wise restoration, 1 chunk".into(), fmt_bytes(one_cw)],
        vec!["chunk-wise restoration, 7 concurrent".into(), fmt_bytes(7 * one_cw)],
        vec!["CacheGen CUDA buffer (2.7x), 1 chunk".into(), fmt_bytes(one_cg)],
    ];
    println!("{}", markdown(&["configuration", "peak device memory"], &rows));

    println!(
        "\npaper: 7 concurrent chunks ~400MB (frame-wise) vs 1.5-2GB per chunk\n\
         (chunk-wise), CacheGen 2.7x raw (5.5GB for 4K tokens of a 7B model)."
    );
    assert!(7 * one_fw < 1024 * 1024 * 1024, "7 concurrent frame-wise chunks must stay <1GB");
    assert!(one_cw > 4 * one_fw, "chunk-wise must dwarf frame-wise");
    assert!(one_cg > one_cw, "CacheGen bloat exceeds even chunk-wise restore");
}
