//! Ablation (Appx. A.3 / §4 Compatibility) — layer-wise fetch/compute
//! pipelining: a fetch request may enter the running queue before its
//! last layer arrives, provided every layer's KV lands before compute
//! reaches it. Compares fetch-request TTFT with the pipeline on vs off
//! across bandwidths, plus the admission-rule unit economics.

use kvfetcher::baselines::SystemProfile;
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::engine::{EngineConfig, EngineSim};
use kvfetcher::fetcher::layerwise_admission;
use kvfetcher::net::BandwidthTrace;
use kvfetcher::trace::{generate, TraceConfig};
use kvfetcher::util::table::{fmt_secs, markdown};

fn main() {
    println!("# Ablation — layer-wise fetch/compute pipeline (Appx. A.3)\n");
    let perf = PerfModel::new(DeviceSpec::h20(), ModelSpec::yi_34b());
    let trace = generate(&TraceConfig {
        seed: 33,
        n_requests: 16,
        rate: 0.05, // isolated requests: pure pipeline effect
        ctx_min: 60_000,
        ctx_max: 160_000,
        reuse_frac: 1.0,
        reuse_threshold: 40_000,
        reuse_share: 0.9, // a 10% suffix gives compute to overlap with
        ..Default::default()
    });

    let mut rows = Vec::new();
    for bw in [2.0, 4.0, 8.0, 16.0] {
        let run = |layerwise: bool| {
            let cfg = EngineConfig { layerwise_pipeline: layerwise, ..Default::default() };
            EngineSim::new(
                perf.clone(),
                SystemProfile::kvfetcher(),
                cfg,
                BandwidthTrace::constant(bw),
            )
            .run(&trace)
            .ttft_summary(Some(true))
        };
        let with = run(true);
        let without = run(false);
        // earlier admission of one request can occasionally delay a
        // neighbour's batch slot (work-conserving schedulers are not
        // TTFT-monotone per request), so allow a small tolerance on the
        // aggregate; isolated requests always win (see dbg below)
        assert!(
            with.mean <= without.mean * 1.05,
            "pipeline must not hurt materially: {} vs {} at {bw} Gbps",
            with.mean,
            without.mean
        );
        rows.push(vec![
            format!("{bw} Gbps"),
            fmt_secs(without.mean),
            fmt_secs(with.mean),
            format!("{:.1}%", (1.0 - with.mean / without.mean) * 100.0),
        ]);
    }
    println!(
        "{}",
        markdown(
            &["bandwidth", "fetch TTFT (no pipeline)", "fetch TTFT (layer-wise)", "saving"],
            &rows
        )
    );

    // admission-rule micro-view: when compute per layer covers the
    // per-layer fetch time, admission is immediate after layer 1
    println!("\nadmission rule examples (fetch [0,10s], 32 layers):");
    let mut rows = Vec::new();
    for per_layer in [0.0, 0.1, 0.3, 0.5, 1.0] {
        let admit = layerwise_admission(0.0, 10.0, 32, per_layer, 0);
        rows.push(vec![
            format!("{per_layer:.1}s/layer compute"),
            fmt_secs(admit),
            fmt_secs((10.0f64 - admit).max(0.0)),
        ]);
    }
    println!("{}", markdown(&["compute speed", "admit at", "overlap won"], &rows));
    println!(
        "paper: the non-blocking condition hides the remaining layers' fetch\nbehind \
         inference, eliminating the pipeline bubbles of the layer-wise design."
    );
}
