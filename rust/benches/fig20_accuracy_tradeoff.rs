//! Fig. 20 — accuracy + compression ratio per system across "datasets"
//! (here: disjoint random-prompt pools standing in for L-Eval /
//! LV-Eval / LongBench-V2), with REAL inference through PJRT.
//! Requires `make artifacts`.

use kvfetcher::engine::real::{accuracy_eval, WireCoding};
use kvfetcher::runtime::Runtime;
use kvfetcher::util::table::markdown;

fn main() {
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("fig20: artifacts missing ({e}); run `make artifacts` first");
            std::process::exit(0);
        }
    };
    println!("# Fig. 20 — accuracy & compression per system x dataset (real model)\n");
    let datasets = [("l-eval", 101u64), ("lv-eval", 202), ("longbench-v2", 303)];
    let systems: [(WireCoding, &'static str); 4] = [
        (WireCoding::Entropy, "CacheGen"),
        (WireCoding::Entropy, "ShadowServe"),
        (WireCoding::Llm265, "llm.265"),
        (WireCoding::LosslessVideo, "KVFetcher"),
    ];

    for (ds, seed) in datasets {
        println!("## dataset proxy: {ds}");
        let mut rows = Vec::new();
        let mut acc = std::collections::BTreeMap::new();
        for (coding, name) in systems {
            let p = accuracy_eval(&rt, coding, name, 4, seed).expect("eval");
            acc.insert(name, p.agreement);
            rows.push(vec![
                name.to_string(),
                format!("{:.1}%", p.agreement * 100.0),
                format!("{:.2}x", p.compression_ratio),
            ]);
        }
        println!("{}", markdown(&["system", "accuracy (agreement)", "ratio"], &rows));
        assert!(
            acc["KVFetcher"] >= acc["llm.265"],
            "lossless KVFetcher must not lose to lossy llm.265"
        );
    }
    println!(
        "paper shape check: KVFetcher matches the lossless baselines' accuracy\n\
         exactly (same quantization) while compressing the most; llm.265 pays\n\
         ~12% accuracy for its ratio."
    );
}
