//! Fig. 21 — heatmap of CacheGen TTFT ÷ KVFetcher TTFT over
//! bandwidth (1-40 Gbps+) x context (20K-200K). Paper: 1.29x-3.50x
//! average gain below 40 Gbps, diminishing as bandwidth grows.

use kvfetcher::baselines::SystemProfile;
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::engine::ExecMode;
use kvfetcher::fetcher::Fetcher;
use kvfetcher::net::BandwidthTrace;

const BANDWIDTHS: [f64; 7] = [1.0, 2.0, 4.0, 8.0, 16.0, 40.0, 100.0];
const CONTEXTS: [usize; 5] = [20_000, 50_000, 100_000, 150_000, 200_000];

fn main() {
    println!("# Fig. 21 — CacheGen TTFT / KVFetcher TTFT (LWM-7B on 2x H20)\n");
    let dev = DeviceSpec::h20();
    let perf = PerfModel::new(dev.clone(), ModelSpec::lwm_7b());
    let ours = SystemProfile::kvfetcher();
    let cg = SystemProfile::cachegen(&dev);
    let ttft = |p: &SystemProfile, tr: &BandwidthTrace, ctx: usize, reusable: usize| {
        Fetcher::builder()
            .profile(p.clone())
            .bandwidth(tr.clone())
            .for_perf(&perf)
            .build()
            .ttft(&perf, ctx, reusable, ExecMode::Analytic)
            .total()
    };

    print!("{:>9} |", "ctx\\bw");
    for bw in BANDWIDTHS {
        print!("{:>6} ", format!("{bw}G"));
    }
    println!();
    println!("{}", "-".repeat(11 + 7 * BANDWIDTHS.len()));
    let mut low_bw_ratios = Vec::new();
    let mut hi_bw_ratios = Vec::new();
    for ctx in CONTEXTS {
        print!("{:>9} |", format!("{}K", ctx / 1000));
        let reusable = (ctx as f64 * 0.95) as usize;
        for bw in BANDWIDTHS {
            let tr = BandwidthTrace::constant(bw);
            let t_ours = ttft(&ours, &tr, ctx, reusable);
            let t_cg = ttft(&cg, &tr, ctx, reusable);
            let ratio = t_cg / t_ours;
            if bw <= 40.0 {
                low_bw_ratios.push(ratio);
            } else {
                hi_bw_ratios.push(ratio);
            }
            print!("{:>6} ", format!("{ratio:.2}"));
        }
        println!();
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let amax = |v: &[f64]| v.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "\n<=40 Gbps: mean {:.2}x, max {:.2}x (paper: 1.29x-3.50x range); >40 Gbps mean {:.2}x",
        avg(&low_bw_ratios),
        amax(&low_bw_ratios),
        avg(&hi_bw_ratios)
    );
    assert!(avg(&low_bw_ratios) > 1.0, "KVFetcher must beat CacheGen below 40 Gbps");
    assert!(
        avg(&hi_bw_ratios) < amax(&low_bw_ratios),
        "the gain must diminish as bandwidth grows"
    );
}
