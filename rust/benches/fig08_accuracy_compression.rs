//! Fig. 8 — accuracy vs compression-ratio tradeoff of the encoding
//! configurations (Default, QP0, Lossless/KVFetcher, llm.265,
//! CacheGen-entropy, raw), measured with REAL inference: the AOT tiny
//! model runs via PJRT, its prefix KV goes through each real coding
//! pipeline, and next-token agreement vs the fp32 full prefill is
//! reported. Requires `make artifacts`.

use kvfetcher::engine::real::{accuracy_eval, WireCoding};
use kvfetcher::runtime::Runtime;
use kvfetcher::util::table::markdown;

fn main() {
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("fig08: artifacts missing ({e}); run `make artifacts` first");
            std::process::exit(0); // skip, don't fail the bench suite
        }
    };
    println!("# Fig. 8 — accuracy vs compression (real model, {} samples/coding)", 6);

    let configs: [(WireCoding, &'static str); 6] = [
        (WireCoding::Raw, "Raw KV (fp32)"),
        (WireCoding::Entropy, "CacheGen/ShadowServe (entropy)"),
        (WireCoding::LosslessVideo, "KVFetcher (lossless video)"),
        (WireCoding::Llm265, "llm.265 (lossy, no inter-pred)"),
        (WireCoding::LossyVideo { qp: 4 }, "QP0 (lossy video)"),
        (WireCoding::LossyVideo { qp: 20 }, "Default (lossy video)"),
    ];
    let mut rows = Vec::new();
    for (coding, name) in configs {
        let p = accuracy_eval(&rt, coding, name, 6, 99).expect("eval");
        rows.push(vec![
            name.to_string(),
            format!("{:.1}%", p.agreement * 100.0),
            format!("{:.2}x", p.compression_ratio),
        ]);
    }
    println!("{}", markdown(&["coding", "next-token agreement", "ratio vs fp16"], &rows));
    println!(
        "\npaper shape check: lossless configs (raw/entropy/KVFetcher) sit at the\n\
         top-accuracy line with KVFetcher the most compact of them; lossy configs\n\
         (Default/QP0/llm.265) trade accuracy for ratio. Absolute ratios are lower\n\
         than the paper's 11.9x because our entropy stage is order-0 rANS, not\n\
         H.265 CABAC (see EXPERIMENTS.md)."
    );
}
