//! Fig. 14 — the intra-frame layout search: rule-reduced candidate
//! space (O(log H x log D)), per-tiling compression ratios, and the
//! selected optimum. Also validates the three reduction rules by
//! measuring what breaking them costs (§3.2.2's 2.4x / 17% numbers).

use kvfetcher::codec::{encode_video, CodecConfig};
use kvfetcher::layout::{self, baseline::llm265_frames, IntraLayout};
use kvfetcher::quant::quantize;
use kvfetcher::tensor::KvCache;
use kvfetcher::util::table::markdown;
use kvfetcher::util::Prng;

fn main() {
    println!("# Fig. 14 — intra-frame layout search\n");
    // paper example dims: 32 heads x 128 dim -> d(32)*d(128) = 48 tilings
    println!(
        "search-space sizes: 32x128 -> {} candidates (paper: ~35-48 \"few dozen\"); \
         8x32 -> {}",
        layout::candidates(32, 128).len(),
        layout::candidates(8, 32).len()
    );

    let mut rng = Prng::new(14);
    let kv = KvCache::synthetic(&mut rng, 192, 3, 8, 32, 0.97);
    let q = quantize(&kv);
    let t0 = std::time::Instant::now();
    let rows_raw = layout::search(&q, 192, 256, 144);
    let took = t0.elapsed().as_secs_f64();

    let rows: Vec<Vec<String>> = rows_raw
        .iter()
        .map(|r| {
            vec![
                format!("H({},{}) D({},{})", r.layout.hr, r.layout.hc, r.layout.dr, r.layout.dc),
                format!("{}x{}", r.layout.tile_h(), r.layout.tile_w()),
                r.encoded_bytes.to_string(),
                format!("{:.2}x", r.ratio),
            ]
        })
        .collect();
    println!("{}", markdown(&["tiling", "tile", "bytes", "ratio"], &rows));
    println!(
        "searched {} feasible tilings in {:.2}s (offline, input-agnostic); best = {:?}\n",
        rows_raw.len(),
        took,
        rows_raw[0].layout
    );

    // Rule (i): exchanging elements across heads destroys compression.
    let mut shuffled = q.clone();
    let chans = q.per_plane_channels();
    let mut prng = Prng::new(99);
    // one fixed random permutation of channel positions across heads,
    // applied to every token identically (a "bad layout", not noise)
    let mut perm: Vec<usize> = (0..chans).collect();
    prng.shuffle(&mut perm);
    for t in 0..q.tokens {
        for p in 0..q.planes {
            let base = (t * q.planes + p) * chans;
            let orig: Vec<u8> = q.data[base..base + chans].to_vec();
            for (i, &src) in perm.iter().enumerate() {
                shuffled.data[base + i] = orig[src];
            }
        }
    }
    let best = rows_raw[0].layout;
    let enc = |qq: &kvfetcher::quant::QuantKv, l: IntraLayout| -> usize {
        let res = kvfetcher::layout::Resolution { name: "s", w: 256, h: 144 };
        layout::encode_chunk(qq, res, l, &CodecConfig::lossless())
            .map(|g| g.iter().map(|x| x.bytes.len()).sum())
            .unwrap_or(usize::MAX)
    };
    let ok = enc(&q, best);
    let broken = enc(&shuffled, best);
    println!(
        "rule (i) check — cross-head element exchange: {} -> {} bytes ({:.2}x worse; paper: \
         2.4x ratio degradation)",
        ok,
        broken,
        broken as f64 / ok as f64
    );
    assert!(broken > ok, "breaking head locality must hurt compression");

    // Rule (iii): head order barely matters (<0.3% size variation).
    let frames_a = llm265_frames(&q); // head order as-is, via layer frames
    let (a, _) = encode_video(&frames_a, &CodecConfig::lossless(), &[]);
    let mut head_perm = q.clone();
    // swap head order (rotate by heads/2), keep inner-head order
    for t in 0..q.tokens {
        for p in 0..q.planes {
            let base = (t * q.planes + p) * chans;
            let orig: Vec<u8> = q.data[base..base + chans].to_vec();
            for h in 0..q.heads {
                let h2 = (h + q.heads / 2) % q.heads;
                head_perm.data[base + h * q.head_dim..base + (h + 1) * q.head_dim]
                    .copy_from_slice(&orig[h2 * q.head_dim..(h2 + 1) * q.head_dim]);
            }
        }
    }
    let (b, _) = encode_video(&llm265_frames(&head_perm), &CodecConfig::lossless(), &[]);
    let delta = (a.len() as f64 - b.len() as f64).abs() / a.len() as f64 * 100.0;
    println!(
        "rule (iii) check — reordering whole heads: {} vs {} bytes ({delta:.2}% change; \
         paper: <0.3%)",
        a.len(),
        b.len()
    );
    assert!(delta < 3.0, "head order must be near-irrelevant, got {delta:.2}%");
}
