//! Fig. 4/5/6 (§2.2 motivation) — CUDA-based decompression contends
//! with LLM inference: concurrent CacheGen decompression inflates
//! prefill (+50%) and decode (+20%) iteration times and bloats memory
//! 2.7x, while the NVDEC path leaves inference untouched.

use kvfetcher::baselines::{Decompress, SystemProfile};
use kvfetcher::cluster::{DeviceSpec, ModelSpec, PerfModel};
use kvfetcher::fetcher::{restore_memory, FetchConfig};
use kvfetcher::util::table::{fmt_bytes, fmt_secs, markdown};

fn main() {
    let dev = DeviceSpec::h20();
    let perf = PerfModel::new(dev.clone(), ModelSpec::yi_34b());
    println!("# Fig. 4/5/6 — decompression interference ({} x{})", dev.name, perf.n_gpus);

    let prefill = perf.prefill_time(8192, 50_000);
    let decode = perf.decode_step_time(&[50_000; 8]);

    let cg = SystemProfile::cachegen(&dev);
    let (pf_slow, dec_slow, mem_f) = match cg.decompress {
        Decompress::CudaKernel { prefill_slowdown, decode_slowdown, mem_factor, .. } => {
            (prefill_slowdown, decode_slowdown, mem_factor)
        }
        _ => unreachable!(),
    };

    let rows = vec![
        vec![
            "prefill iter (8K chunk @50K ctx)".to_string(),
            fmt_secs(prefill),
            fmt_secs(prefill * pf_slow),
            fmt_secs(prefill),
        ],
        vec![
            "decode iter (8x 50K ctx)".to_string(),
            fmt_secs(decode),
            fmt_secs(decode * dec_slow),
            fmt_secs(decode),
        ],
    ];
    println!(
        "{}",
        markdown(
            &["iteration", "standalone", "w/ CacheGen decompress", "w/ KVFetcher (NVDEC)"],
            &rows
        )
    );

    // Fig. 6: memory of decompressing one 4K-token chunk (Yi-34B)
    let raw_4k = perf.kv_bytes(4_096);
    let cfg = FetchConfig::default();
    let mem_rows = vec![
        vec!["raw KV of the chunk".to_string(), fmt_bytes(raw_4k)],
        vec![
            format!("CacheGen decompress buffer ({mem_f}x)"),
            fmt_bytes(restore_memory(&cg, &cfg, raw_4k)),
        ],
        vec![
            "KVFetcher frame-wise buffer".to_string(),
            fmt_bytes(restore_memory(&SystemProfile::kvfetcher(), &cfg, raw_4k)),
        ],
    ];
    println!("{}", markdown(&["buffer", "bytes"], &mem_rows));
    println!(
        "paper: CacheGen +50% prefill / +20% decode while decompressing; 2.7x\n\
         memory bloat (5.5GB for 4K tokens). NVDEC path: zero SM contention."
    );
}
